//! In-process map-reduce runtime — the substitute for the paper's Hadoop
//! deployment (§5, Fig. 3/4). Mappers run on a **persistent worker
//! pool** (threads are spawned once at construction and reused across
//! rounds, so a 1000-round chain pays thread startup once, not 1000
//! times); per-task compute time is measured individually so the
//! **modeled wall-clock** (what a K-machine cluster would see:
//! `max_k(map_k) + reduce + comm`) is well-defined even on a single-core
//! container. The communication cost model is parameterized on per-round
//! latency (Hadoop job overhead) and bandwidth, and drives the Fig. 8
//! saturation behaviour.
//!
//! Two round schedules are modeled (DESIGN.md § Barrier-free rounds):
//! the **bulk-synchronous** schedule serializes map → reduce → comm, and
//! the **overlapped** schedule hides the previous round's shuffle
//! transfer and global updates behind the current map, so the modeled
//! wall is `latency + stats_upload + max(map_crit, carry_prev)` instead
//! of the sum. Completion delivery is a channel, not a barrier: the
//! caller drains completions as tasks finish ([`MapReduce::map_collect`]
//! and, with in-flight reaction + follow-up resubmission,
//! [`MapReduce::map_streaming`]), which is what lets a coordinator stage
//! shuffle state and grant bonus sweeps for fast shards while slow ones
//! are still sweeping. A [`DelayHook`] can inject deterministic per-task
//! start delays so tests can force any completion-order interleaving;
//! its generalization, the [`FaultHook`], additionally injects panics,
//! stalls, and I/O errors at chosen (round, shard, attempt) sites, and
//! [`MapReduce::map_supervised`] turns those failures into supervisor
//! events (retry / watchdog-timeout / quarantine decisions) instead of
//! round aborts — the recovery surface supervised coordinator rounds
//! run on (DESIGN.md §12).

use std::any::Any;
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Test/diagnostics hook: given a task index, return an artificial delay
/// the pool sleeps **before** starting that task's compute (excluded
/// from the task's measured duration). This makes completion order a
/// deterministic function of the hook, which is how the concurrency
/// test layer exercises every interleaving; a panicking hook doubles as
/// an injected shard failure. Kept as the back-compat surface over the
/// generalized [`FaultHook`] ([`MapReduce::set_delay_hook`] adapts it).
pub type DelayHook = Arc<dyn Fn(usize) -> Duration + Send + Sync>;

/// Where a fault is (or is not) injected: one attempt of one map task in
/// one round. `attempt` is the retry generation under supervision
/// (0 = first try), so a hook can fail the first attempt and let the
/// retry through, or fail every attempt to force quarantine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSite {
    /// the coordinator round ([`MapReduce::set_fault_round`])
    pub round: u64,
    /// input index of the map task (= shard index)
    pub task: usize,
    /// retry generation of the attempt (0 on unsupervised paths)
    pub attempt: u32,
}

/// What a [`FaultHook`] injects before one attempt's compute starts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultAction {
    /// run normally
    None,
    /// sleep before compute (excluded from the measured duration) — the
    /// legacy [`DelayHook`] completion-order lever
    Delay(Duration),
    /// sleep like a wedged worker: identical mechanics to `Delay`, named
    /// separately because its purpose is tripping a supervised watchdog
    Stall(Duration),
    /// panic in place of the compute (a crashed worker)
    Panic(String),
    /// fail with an I/O-style error without running the compute (a
    /// worker that lost its data / connection)
    Io(String),
}

/// Deterministic per-(round, task, attempt) fault injection — the
/// generalization of [`DelayHook`] the fault-tolerance harness drives
/// (`rust/tests/fault_tolerance.rs`). On the unsupervised map paths a
/// `Panic`/`Io` action aborts the round exactly like an organic shard
/// panic; under [`MapReduce::map_supervised`] it is caught and reported
/// to the supervisor instead.
pub type FaultHook = Arc<dyn Fn(FaultSite) -> FaultAction + Send + Sync>;

/// Best-effort human-readable panic payload.
fn panic_message(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "task panicked".to_string()
    }
}

/// Apply an injected fault on an **unsupervised** map path: delays and
/// stalls sleep; panics and I/O errors abort the task, which the legacy
/// paths drain and then propagate (the pinned poisoned-coordinator
/// contract of `rust/tests/failure_injection.rs`).
fn apply_fault_unsupervised(action: FaultAction) {
    match action {
        FaultAction::None => {}
        FaultAction::Delay(d) | FaultAction::Stall(d) => std::thread::sleep(d),
        FaultAction::Panic(msg) => panic!("injected fault: {msg}"),
        FaultAction::Io(msg) => panic!("injected I/O error: {msg}"),
    }
}

/// Communication/overhead model for one map-reduce round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    /// fixed per-round overhead (job scheduling, barrier, shuffle start).
    /// The paper's Hadoop-era overhead is seconds; default reflects a
    /// modest cluster (tunable from every bench/CLI).
    pub round_latency_s: f64,
    /// per-worker connection setup cost
    pub per_worker_latency_s: f64,
    /// bytes/second for state transfer (both directions pooled)
    pub bandwidth_bytes_per_s: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel {
            round_latency_s: 2.0,           // Hadoop job launch overhead
            per_worker_latency_s: 0.05,     // per-mapper startup
            bandwidth_bytes_per_s: 100e6,   // ~1 Gb/s effective
        }
    }
}

impl CommModel {
    /// No communication cost at all (pure algorithmic comparisons).
    pub fn free() -> Self {
        CommModel {
            round_latency_s: 0.0,
            per_worker_latency_s: 0.0,
            bandwidth_bytes_per_s: f64::INFINITY,
        }
    }

    /// Modeled communication time for a round with `workers` mappers
    /// moving `bytes` of state.
    pub fn round_time(&self, workers: usize, bytes: u64) -> f64 {
        self.round_latency_s
            + self.per_worker_latency_s * workers as f64
            + bytes as f64 / self.bandwidth_bytes_per_s
    }

    /// Modeled wall-clock of one **overlapped** round. Only the small
    /// reduced-statistics upload (`stats_bytes`: J_k counts, pooled dim
    /// stats) sits on the critical path; the bulky shuffle transfer and
    /// the global-update compute of the *previous* round (`carry_s`)
    /// ride behind the current map, so the round pays
    /// `max(map_crit_s, carry_s)` instead of their sum.
    pub fn overlapped_round_time(
        &self,
        workers: usize,
        stats_bytes: u64,
        map_crit_s: f64,
        carry_s: f64,
    ) -> f64 {
        self.round_latency_s
            + self.per_worker_latency_s * workers as f64
            + stats_bytes as f64 / self.bandwidth_bytes_per_s
            + map_crit_s.max(carry_s)
    }
}

/// Timing/traffic record of one map-reduce round.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    /// measured compute duration of each map task (base + any follow-up
    /// grants, pooled per task)
    pub map_durations: Vec<Duration>,
    /// measured host-side non-map duration attributed to the round's
    /// reduce/global step. Under the overlapped schedule this is the
    /// staging work absorbed into the map window **plus** the post-window
    /// tail (shuffle decisions + hyper reduce), i.e. everything the bulk
    /// schedule would serialize after the map barrier.
    pub reduce_duration: Duration,
    /// bytes the round moved (stats up + state down)
    pub bytes_transferred: u64,
    /// modeled distributed wall-clock for the round (seconds) under the
    /// schedule the round actually ran: equals [`Self::modeled_bulk_s`]
    /// for bulk-synchronous rounds and [`Self::modeled_overlapped_s`]
    /// for overlapped rounds
    pub modeled_wall_s: f64,
    /// modeled wall under the bulk-synchronous schedule
    /// (`max_k(map_k) + reduce + comm`), always populated so the two
    /// schedules stay comparable round-by-round
    pub modeled_bulk_s: f64,
    /// modeled wall under the overlapped schedule
    /// (`latency + stats_upload + max(map_crit, carry_prev)`); for a
    /// bulk round this is reported equal to the bulk figure (no carry
    /// was tracked, so no overlap is claimed)
    pub modeled_overlapped_s: f64,
    /// actually measured wall-clock on this host (seconds)
    pub measured_wall_s: f64,
    /// measured wall-clock of the round as actually executed on this
    /// host under its own schedule. For an overlapped round this equals
    /// [`Self::measured_wall_s`] (the concurrent pipeline is what ran);
    /// for a bulk round it is also the measured wall (no concurrency was
    /// attempted, none is claimed).
    pub measured_overlapped_s: f64,
    /// measured wall-clock this host *would* have paid had it serialized
    /// the same round bulk-style: the map window plus every piece of
    /// host work the concurrent schedule hid inside it (per-completion
    /// staging) or ran after it (shuffle + reduce tail). The ratio
    /// `measured_serialized_s / measured_overlapped_s` is the **real**
    /// (not modeled) host overlap speedup. For a bulk round both
    /// measured columns equal [`Self::measured_wall_s`].
    pub measured_serialized_s: f64,
    /// shard-sweep retries the round's supervisor performed (0 unless
    /// supervision is on and faults occurred); set by the coordinator
    /// after assembly
    pub retries: u64,
    /// watchdog deadline expirations during the round's map window
    pub watchdog_fires: u64,
    /// shards that ran this round degraded (quarantined: assignments
    /// frozen, sweep skipped, stats still folded into the reduces)
    pub quarantined_shards: u64,
}

impl RoundStats {
    /// max_k map time — the parallel critical path.
    pub fn map_critical_path(&self) -> Duration {
        self.map_durations.iter().copied().max().unwrap_or_default()
    }

    /// Σ_k map time — what a serial execution would pay.
    pub fn map_total(&self) -> Duration {
        self.map_durations.iter().sum()
    }
}

/// A type-erased unit of work shipped to the pool. Jobs are *logically*
/// non-`'static` (they borrow the caller's stack); [`MapReduce::map`]
/// guarantees completion before returning, which is what makes the
/// lifetime erasure sound — see the safety comment there.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// The persistent worker threads. Shared one `Receiver` behind a mutex
/// (the lock is held while idle-waiting in `recv`, which serializes job
/// *pickup*, not execution — pickup is nanoseconds against millisecond
/// sweep tasks). Dropping the pool closes the channel and joins.
struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    fn new(threads: usize) -> WorkerPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed: pool dropped
                    }
                })
            })
            .collect();
        WorkerPool {
            tx: Some(tx),
            handles,
        }
    }

    fn submit(&self, job: Job) {
        self.tx
            .as_ref()
            .expect("pool sender alive until drop")
            .send(job)
            .expect("worker pool alive");
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel so workers exit their loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One completion event delivered to the [`MapReduce::map_streaming`]
/// reaction callback, on the **caller** thread, as tasks (and follow-up
/// grants) finish.
pub struct StreamEvent<'a, R> {
    /// 0-based completion order of this event among all reacted events
    pub rank: usize,
    /// input index of the task that finished
    pub index: usize,
    /// how many follow-up grants this task has already completed
    /// (0 = this is the base task's completion)
    pub followups_done: usize,
    /// measured compute duration of just this unit of work (base task or
    /// single follow-up; injected delays excluded)
    pub duration: Duration,
    /// the task's current result; mutable so the reaction can stage
    /// state out of it before deciding whether to grant a follow-up
    pub result: &'a mut R,
}

/// What happened to the live attempt a [`SupervisedEvent`] reports.
pub enum SupervisedOutcome<'a, R> {
    /// the attempt (or one of its follow-up grants) completed; the
    /// supervisor can stage state out of the mutable result
    Done(&'a mut R),
    /// the attempt panicked — organically or via an injected
    /// [`FaultAction::Panic`] — or hit an injected [`FaultAction::Io`];
    /// the payload is the panic/error message
    Failed(String),
    /// the watchdog deadline passed with this task's live attempt still
    /// outstanding (a stalled worker)
    TimedOut,
}

/// One event delivered to the [`MapReduce::map_supervised`] supervisor
/// callback, on the **caller** thread.
pub struct SupervisedEvent<'a, R> {
    /// input index of the task
    pub index: usize,
    /// retry generation of the live attempt (0 = first try)
    pub attempt: u32,
    /// follow-up grants this attempt has already completed
    /// (meaningful for [`SupervisedOutcome::Done`] only)
    pub followups_done: usize,
    /// measured compute duration of just the completed unit
    /// ([`Duration::ZERO`] for `Failed`/`TimedOut`)
    pub duration: Duration,
    /// what happened
    pub outcome: SupervisedOutcome<'a, R>,
}

/// The supervisor's verdict on a [`SupervisedEvent`].
///
/// Validity per outcome: after `Done`, all four make sense (`Retire`
/// keeps the result). After `Failed`/`TimedOut` only `Respawn` and
/// `Abandon` are meaningful; `Retire`/`Follow` there settle the task
/// with no result, same as `Abandon` (there is no result to keep).
pub enum SupervisedDirective<T> {
    /// settle the task, keeping the result (Done only)
    Retire,
    /// grant one follow-up unit through the `follow` closure (Done only)
    Follow,
    /// start a fresh attempt from this input after sleeping the backoff
    /// on the worker thread (excluded from measured durations). Any
    /// still-outstanding older attempt for the index is superseded: its
    /// eventual completion is drained and discarded, never reported.
    Respawn(T, Duration),
    /// settle the task with **no** result (`results[index] = None`)
    Abandon,
}

/// The map-reduce executor. `parallelism` caps the number of worker
/// threads (tasks beyond it queue, exactly like mappers on a small
/// cluster). Workers are spawned once here and reused by every
/// subsequent [`Self::map`] round.
pub struct MapReduce {
    parallelism: usize,
    pool: Option<WorkerPool>,
    fault: Option<FaultHook>,
    /// round tag stamped into every [`FaultSite`] this executor consults
    fault_round: u64,
}

impl std::fmt::Debug for MapReduce {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MapReduce")
            .field("parallelism", &self.parallelism)
            .field("pooled", &self.pool.is_some())
            .field("faulted", &self.fault.is_some())
            .finish()
    }
}

impl MapReduce {
    /// Executor with `parallelism` persistent worker threads (≥ 1).
    pub fn new(parallelism: usize) -> Self {
        assert!(parallelism >= 1);
        // parallelism == 1 runs inline on the caller thread: no pool,
        // no thread overhead, cleanest per-task timing on one core
        let pool = (parallelism > 1).then(|| WorkerPool::new(parallelism));
        MapReduce {
            parallelism,
            pool,
            fault: None,
            fault_round: 0,
        }
    }

    /// Use all available cores.
    pub fn host_parallel() -> Self {
        let p = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        MapReduce::new(p)
    }

    /// The configured worker-thread cap.
    pub fn parallelism(&self) -> usize {
        self.parallelism
    }

    /// Install (or clear) a [`DelayHook`]. Applied to **base** tasks
    /// only, before their compute starts, on whichever thread runs the
    /// task; the sleep is excluded from measured durations. Tests use
    /// this to pin completion order deterministically and to inject
    /// mid-map failures (a panicking hook behaves like a crashed shard).
    ///
    /// Back-compat adapter over [`Self::set_fault_hook`]: the delay is
    /// applied on first attempts (`attempt == 0`); supervised retries of
    /// a task run undelayed.
    pub fn set_delay_hook(&mut self, hook: Option<DelayHook>) {
        self.fault = hook.map(|h| -> FaultHook {
            Arc::new(move |site: FaultSite| {
                if site.attempt == 0 {
                    FaultAction::Delay(h(site.task))
                } else {
                    FaultAction::None
                }
            })
        });
    }

    /// Install (or clear) a [`FaultHook`]. Consulted once per **base**
    /// attempt (follow-up grants never consult it, matching the
    /// [`DelayHook`] contract), before the attempt's compute starts, on
    /// whichever thread runs it. Replaces any hook installed by
    /// [`Self::set_delay_hook`] and vice versa.
    pub fn set_fault_hook(&mut self, hook: Option<FaultHook>) {
        self.fault = hook;
    }

    /// Set the round tag stamped into [`FaultSite::round`] for
    /// subsequent map calls (the coordinator calls this at the top of
    /// every round so hooks can target "round 3, shard 1").
    pub fn set_fault_round(&mut self, round: u64) {
        self.fault_round = round;
    }

    /// Run `f` over `tasks`, returning results (input order) and each
    /// task's measured compute duration (queue wait excluded). Tasks are
    /// distributed over the persistent pool; with `parallelism == 1`
    /// (or a single task) execution is in-place.
    pub fn map<T, R, F>(&self, tasks: Vec<T>, f: F) -> (Vec<R>, Vec<Duration>)
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        self.map_collect(tasks, f, |_, _| {})
    }

    /// Like [`Self::map`], but the caller observes completions as they
    /// happen: `on_done(rank, index)` runs on the **caller** thread when
    /// the `rank`-th task to finish (0-based completion order) turns out
    /// to be input `index`. Results are still returned in **input
    /// order**: every completion message carries its task index, so
    /// out-of-order execution cannot scramble the output vector or the
    /// per-task duration vector.
    ///
    /// If a task panics, the first payload is re-raised on the caller
    /// thread — but only after all completions (success or panic) have
    /// been drained, so a panicking task can never wedge the pool or
    /// leave a borrow live. `on_done` is not invoked for the panicking
    /// task(s).
    pub fn map_collect<T, R, F, C>(
        &self,
        tasks: Vec<T>,
        f: F,
        mut on_done: C,
    ) -> (Vec<R>, Vec<Duration>)
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
        C: FnMut(usize, usize),
    {
        self.map_streaming(
            tasks,
            f,
            |_, r| r,
            |ev| {
                on_done(ev.rank, ev.index);
                false
            },
        )
    }

    /// The full streaming surface the barrier-free coordinator builds
    /// on. Each task `i` runs `f(i, task)` on the pool; when a unit of
    /// work completes, `react` is invoked on the **caller** thread with
    /// a [`StreamEvent`] holding mutable access to the task's current
    /// result — the reaction can stage state out of it (e.g. drain
    /// clusters for the shuffle) and then decide: return `true` to
    /// resubmit the task through `follow(i, result)` as a fresh pool job
    /// (a mid-round bonus-sweep grant), or `false` to retire it. Follow-
    /// up completions re-enter `react` with `followups_done`
    /// incremented, so a task can be granted repeatedly.
    ///
    /// Returned durations pool each task's base + follow-up compute.
    /// Results come back in input order regardless of completion order.
    ///
    /// Panic semantics match [`Self::map_collect`]: the first payload is
    /// re-raised on the caller thread only after every outstanding unit
    /// (base or follow-up) has been drained; once a panic is seen,
    /// `react` is not invoked again (so no further grants are issued)
    /// and the remaining completions are simply accounted for. An
    /// installed [`DelayHook`] delays base tasks only.
    pub fn map_streaming<T, R, F, G, C>(
        &self,
        tasks: Vec<T>,
        f: F,
        follow: G,
        mut react: C,
    ) -> (Vec<R>, Vec<Duration>)
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
        G: Fn(usize, R) -> R + Sync,
        C: FnMut(StreamEvent<'_, R>) -> bool,
    {
        let n = tasks.len();
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        let pool = match &self.pool {
            Some(pool) if n > 1 => pool,
            _ => {
                // inline: completion order == input order, reactions and
                // follow-ups interleave synchronously on this thread
                let mut out = Vec::with_capacity(n);
                let mut durs = Vec::with_capacity(n);
                let mut rank = 0usize;
                for (i, t) in tasks.into_iter().enumerate() {
                    if let Some(hook) = &self.fault {
                        apply_fault_unsupervised(hook(FaultSite {
                            round: self.fault_round,
                            task: i,
                            attempt: 0,
                        }));
                    }
                    let t0 = Instant::now();
                    let mut r = f(i, t);
                    let mut unit = t0.elapsed();
                    let mut total = unit;
                    let mut followups_done = 0usize;
                    loop {
                        let resubmit = react(StreamEvent {
                            rank,
                            index: i,
                            followups_done,
                            duration: unit,
                            result: &mut r,
                        });
                        rank += 1;
                        if !resubmit {
                            break;
                        }
                        let t1 = Instant::now();
                        r = follow(i, r);
                        unit = t1.elapsed();
                        total += unit;
                        followups_done += 1;
                    }
                    out.push(r);
                    durs.push(total);
                }
                return (out, durs);
            }
        };

        // Hand each task to the pool as a type-erased job. The jobs
        // borrow this stack frame (`inputs`, `f`, `follow`, the delay
        // hook), so their lifetime is transmuted up to 'static.
        //
        // SAFETY: every borrow the jobs capture outlives the jobs
        // themselves because this function blocks on the completion
        // drain below until ALL outstanding units (base jobs plus every
        // follow-up this loop itself submitted) have sent their message
        // (panicking jobs are caught and still send one), and the pool
        // can only execute a job once. The `outstanding` counter is
        // incremented before each follow-up submission on this thread,
        // so the drain condition accounts for every job that can ever
        // exist. Nothing below the drain loop can observe a live job.
        // There is deliberately NO public handle type that would let a
        // caller forget a pending job — the drain is unconditional.
        let inputs: Vec<Mutex<Option<T>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        // (index, followups_done, result-or-panic) per completed unit
        let (done_tx, done_rx) =
            channel::<(usize, usize, Result<(R, Duration), Box<dyn Any + Send>>)>();
        // `Sender<Job>` is not Sync, so jobs must not capture `&self`;
        // borrow just the hook (an Option<&Arc<..>> is Send + Sync)
        let fault = self.fault.as_ref();
        let fault_round = self.fault_round;
        for i in 0..n {
            let inputs = &inputs;
            let f = &f;
            let done_tx = done_tx.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    if let Some(hook) = fault {
                        apply_fault_unsupervised(hook(FaultSite {
                            round: fault_round,
                            task: i,
                            attempt: 0,
                        }));
                    }
                    let t = inputs[i].lock().unwrap().take().expect("task taken once");
                    let t0 = Instant::now();
                    let r = f(i, t);
                    (r, t0.elapsed())
                }));
                // only fails if the receiver is gone, which the
                // unconditional drain below rules out
                let _ = done_tx.send((i, 0, ran));
            });
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
            };
            pool.submit(job);
        }
        // keep `done_tx` alive: follow-up jobs clone their sender from
        // the drain loop below, and dropping the original only after the
        // drain keeps the channel trivially open throughout
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut totals: Vec<Duration> = vec![Duration::ZERO; n];
        let mut outstanding = n;
        let mut rank = 0usize;
        let mut panic_payload: Option<Box<dyn Any + Send>> = None;
        while outstanding > 0 {
            let (i, followups_done, ran) =
                done_rx.recv().expect("every job sends a completion");
            outstanding -= 1;
            match ran {
                Ok((mut r, d)) => {
                    totals[i] += d;
                    let mut resubmit = false;
                    if panic_payload.is_none() {
                        resubmit = react(StreamEvent {
                            rank,
                            index: i,
                            followups_done,
                            duration: d,
                            result: &mut r,
                        });
                        rank += 1;
                    }
                    if resubmit {
                        let follow = &follow;
                        let done_tx = done_tx.clone();
                        let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                            let ran =
                                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                    let t0 = Instant::now();
                                    let r = follow(i, r);
                                    (r, t0.elapsed())
                                }));
                            let _ = done_tx.send((i, followups_done + 1, ran));
                        });
                        let job: Job = unsafe {
                            std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
                        };
                        outstanding += 1;
                        pool.submit(job);
                    } else {
                        slots[i] = Some(r);
                    }
                }
                Err(p) => {
                    if panic_payload.is_none() {
                        panic_payload = Some(p);
                    }
                }
            }
        }
        drop(done_tx);
        if let Some(p) = panic_payload {
            std::panic::resume_unwind(p);
        }

        let mut out = Vec::with_capacity(n);
        for s in slots {
            out.push(s.expect("task not executed"));
        }
        (out, totals)
    }

    /// The fault-tolerant map surface supervised coordinator rounds run
    /// on. Like [`Self::map_streaming`], but failures are **events, not
    /// aborts**: a panicking or injected-I/O-failing attempt is caught
    /// and reported to `react` as [`SupervisedOutcome::Failed`]; if
    /// `timeout` is set and no completion arrives within it, every
    /// unsettled task gets a [`SupervisedOutcome::TimedOut`] event (and
    /// the deadline re-arms). The supervisor answers each event with a
    /// [`SupervisedDirective`] — retry from a fresh input
    /// (`Respawn`), grant a bonus unit (`Follow`), keep the result
    /// (`Retire`), or give up on the task (`Abandon`).
    ///
    /// Returns per-task results in input order (`None` for abandoned
    /// tasks) and pooled compute durations of each task's **surviving**
    /// lineage (superseded attempts contribute nothing).
    ///
    /// Supersession: a `Respawn` makes any still-outstanding older
    /// attempt for that index *stale* — the runner drains its eventual
    /// completion and discards it without reporting. Each attempt owns
    /// its input by value, so a stale attempt can never consume the
    /// respawned attempt's input. The [`FaultHook`] is consulted once
    /// per base attempt with the true `attempt` number; follow-up grants
    /// never consult it.
    ///
    /// Caveats (documented, asserted nowhere): on the inline path
    /// (`parallelism == 1`) the watchdog cannot preempt a running
    /// closure, so `TimedOut` never fires there; on the pooled path a
    /// *genuinely* unbounded stall wedges the final drain — the watchdog
    /// bounds how long the round *waits* for a straggler, not the
    /// straggler's own lifetime (that needs process isolation, which the
    /// planned socket transport provides).
    pub fn map_supervised<T, R, F, G, C>(
        &self,
        tasks: Vec<T>,
        f: F,
        follow: G,
        timeout: Option<Duration>,
        mut react: C,
    ) -> (Vec<Option<R>>, Vec<Duration>)
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
        G: Fn(usize, R) -> R + Sync,
        C: FnMut(SupervisedEvent<'_, R>) -> SupervisedDirective<T>,
    {
        let n = tasks.len();
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        let fault = self.fault.as_ref();
        let fault_round = self.fault_round;
        let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut durs: Vec<Duration> = vec![Duration::ZERO; n];

        // One base attempt: backoff sleep, fault consult, compute — all
        // caught. Err carries the failure message.
        let run_base = |i: usize, t: T, attempt: u32, backoff: Duration| {
            if !backoff.is_zero() {
                std::thread::sleep(backoff);
            }
            let action = fault
                .map(|h| {
                    h(FaultSite {
                        round: fault_round,
                        task: i,
                        attempt,
                    })
                })
                .unwrap_or(FaultAction::None);
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                match action {
                    FaultAction::None => {}
                    FaultAction::Delay(d) | FaultAction::Stall(d) => std::thread::sleep(d),
                    FaultAction::Panic(msg) => panic!("injected fault: {msg}"),
                    FaultAction::Io(msg) => return Err(format!("injected I/O error: {msg}")),
                }
                let t0 = Instant::now();
                Ok((f(i, t), t0.elapsed()))
            }));
            match caught {
                Ok(r) => r,
                Err(p) => Err(panic_message(&*p)),
            }
        };

        let pool = match &self.pool {
            Some(pool) if n > 1 => pool,
            _ => {
                // Inline path: attempts run synchronously; no watchdog
                // (nothing concurrent exists to time out).
                for (i, t) in tasks.into_iter().enumerate() {
                    let mut task = t;
                    let mut attempt: u32 = 0;
                    let mut backoff = Duration::ZERO;
                    'attempts: loop {
                        let (mut r, d) = match run_base(i, task, attempt, backoff) {
                            Ok(ok) => ok,
                            Err(msg) => {
                                match react(SupervisedEvent {
                                    index: i,
                                    attempt,
                                    followups_done: 0,
                                    duration: Duration::ZERO,
                                    outcome: SupervisedOutcome::Failed(msg),
                                }) {
                                    SupervisedDirective::Respawn(t2, b) => {
                                        task = t2;
                                        attempt += 1;
                                        backoff = b;
                                        continue 'attempts;
                                    }
                                    _ => break 'attempts, // settle, no result
                                }
                            }
                        };
                        durs[i] += d;
                        let mut followups = 0usize;
                        let mut unit = d;
                        loop {
                            let directive = react(SupervisedEvent {
                                index: i,
                                attempt,
                                followups_done: followups,
                                duration: unit,
                                outcome: SupervisedOutcome::Done(&mut r),
                            });
                            match directive {
                                SupervisedDirective::Retire => {
                                    results[i] = Some(r);
                                    break 'attempts;
                                }
                                SupervisedDirective::Abandon => break 'attempts,
                                SupervisedDirective::Respawn(t2, b) => {
                                    task = t2;
                                    attempt += 1;
                                    backoff = b;
                                    continue 'attempts;
                                }
                                SupervisedDirective::Follow => {
                                    let caught = std::panic::catch_unwind(
                                        std::panic::AssertUnwindSafe(|| {
                                            let t1 = Instant::now();
                                            let r2 = follow(i, r);
                                            (r2, t1.elapsed())
                                        }),
                                    );
                                    match caught {
                                        Ok((r2, d2)) => {
                                            r = r2;
                                            unit = d2;
                                            durs[i] += d2;
                                            followups += 1;
                                        }
                                        Err(p) => {
                                            // a crashed follow-up fails
                                            // the whole attempt
                                            match react(SupervisedEvent {
                                                index: i,
                                                attempt,
                                                followups_done: followups,
                                                duration: Duration::ZERO,
                                                outcome: SupervisedOutcome::Failed(
                                                    panic_message(&*p),
                                                ),
                                            }) {
                                                SupervisedDirective::Respawn(t2, b) => {
                                                    task = t2;
                                                    attempt += 1;
                                                    backoff = b;
                                                    continue 'attempts;
                                                }
                                                _ => break 'attempts,
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
                return (results, durs);
            }
        };

        // Pooled path. Lifetime erasure is sound for the same reason as
        // map_streaming: the drain below is unconditional — it runs
        // until every job ever submitted (base attempts, respawns,
        // follow-ups, including STALE ones) has sent its completion, so
        // no borrow the jobs capture can outlive this frame. Each
        // attempt owns its input `T` by value inside its job closure
        // (no shared input slots), which is what makes supersession
        // race-free: a stale attempt holds a `T` nothing else will ever
        // touch, and its completion is discarded by the generation
        // check below.
        let (done_tx, done_rx) =
            channel::<(usize, u32, usize, Result<(R, Duration), String>)>();
        let spawn_attempt = |t: T, i: usize, attempt: u32, backoff: Duration| -> Job {
            let run_base = &run_base;
            let done_tx = done_tx.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let ran = run_base(i, t, attempt, backoff);
                // only fails if the receiver is gone, which the
                // unconditional drain rules out
                let _ = done_tx.send((i, attempt, 0, ran));
            });
            unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job) }
        };
        let spawn_follow = |r: R, i: usize, attempt: u32, followups_done: usize| -> Job {
            let follow = &follow;
            let done_tx = done_tx.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let t0 = Instant::now();
                    let r2 = follow(i, r);
                    (r2, t0.elapsed())
                }));
                let ran = match caught {
                    Ok(ok) => Ok(ok),
                    Err(p) => Err(panic_message(&*p)),
                };
                let _ = done_tx.send((i, attempt, followups_done + 1, ran));
            });
            unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job) }
        };

        // live_attempt[i]: the only generation whose completions count;
        // anything older is stale. settled[i]: verdict reached (result
        // kept or task abandoned) — live_attempt is bumped on settle so
        // stragglers of the final attempt are stale by construction.
        let mut live_attempt: Vec<u32> = vec![0; n];
        let mut settled: Vec<bool> = vec![false; n];
        let mut outstanding = 0usize;
        for (i, t) in tasks.into_iter().enumerate() {
            outstanding += 1;
            pool.submit(spawn_attempt(t, i, 0, Duration::ZERO));
        }

        let mut deadline = timeout.map(|t| Instant::now() + t);
        while outstanding > 0 {
            // the watchdog is armed only while a verdict is pending;
            // once every task is settled the remaining receives are
            // stale stragglers and a plain blocking recv drains them
            let unsettled = settled.iter().any(|&s| !s);
            let msg = match deadline.filter(|_| unsettled) {
                None => Some(done_rx.recv().expect("every job sends a completion")),
                Some(d) => {
                    let wait = d.saturating_duration_since(Instant::now());
                    match done_rx.recv_timeout(wait) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => {
                            unreachable!("sender held on this frame")
                        }
                    }
                }
            };
            let (i, attempt, followups_done, ran) = match msg {
                None => {
                    // watchdog fired: every unsettled task's live
                    // attempt is reported timed out, in index order
                    for i in 0..n {
                        if settled[i] {
                            continue;
                        }
                        match react(SupervisedEvent {
                            index: i,
                            attempt: live_attempt[i],
                            followups_done: 0,
                            duration: Duration::ZERO,
                            outcome: SupervisedOutcome::TimedOut,
                        }) {
                            SupervisedDirective::Respawn(t2, b) => {
                                live_attempt[i] += 1;
                                outstanding += 1;
                                pool.submit(spawn_attempt(t2, i, live_attempt[i], b));
                            }
                            _ => {
                                settled[i] = true;
                                live_attempt[i] += 1; // stale the straggler
                            }
                        }
                    }
                    deadline = timeout.map(|t| Instant::now() + t);
                    continue;
                }
                Some(m) => m,
            };
            outstanding -= 1;
            if settled[i] || attempt != live_attempt[i] {
                continue; // stale completion of a superseded attempt
            }
            match ran {
                Ok((mut r, d)) => {
                    durs[i] += d;
                    match react(SupervisedEvent {
                        index: i,
                        attempt,
                        followups_done,
                        duration: d,
                        outcome: SupervisedOutcome::Done(&mut r),
                    }) {
                        SupervisedDirective::Retire => {
                            results[i] = Some(r);
                            settled[i] = true;
                            live_attempt[i] += 1;
                        }
                        SupervisedDirective::Abandon => {
                            settled[i] = true;
                            live_attempt[i] += 1;
                        }
                        SupervisedDirective::Follow => {
                            outstanding += 1;
                            pool.submit(spawn_follow(r, i, attempt, followups_done));
                        }
                        SupervisedDirective::Respawn(t2, b) => {
                            live_attempt[i] += 1;
                            outstanding += 1;
                            pool.submit(spawn_attempt(t2, i, live_attempt[i], b));
                        }
                    }
                }
                Err(msg) => {
                    match react(SupervisedEvent {
                        index: i,
                        attempt,
                        followups_done,
                        duration: Duration::ZERO,
                        outcome: SupervisedOutcome::Failed(msg),
                    }) {
                        SupervisedDirective::Respawn(t2, b) => {
                            live_attempt[i] += 1;
                            outstanding += 1;
                            pool.submit(spawn_attempt(t2, i, live_attempt[i], b));
                        }
                        _ => {
                            settled[i] = true;
                            live_attempt[i] += 1;
                        }
                    }
                }
            }
        }
        drop(done_tx);
        (results, durs)
    }
}

/// Real host timings of one overlapped round, fed to
/// [`finish_round_overlapped`] alongside the modeled inputs.
#[derive(Debug, Clone, Copy)]
pub struct OverlappedTiming {
    /// measured wall-clock of the whole round as executed (the
    /// concurrent host pipeline)
    pub wall: Duration,
    /// measured wall-clock of the map window alone: base-task submission
    /// through the last completion drained, staging included (it ran
    /// inside the window, on the coordinator thread, between drains)
    pub window: Duration,
}

/// Assemble a [`RoundStats`] from measured pieces + the comm model,
/// under the **bulk-synchronous** schedule (`max_k(map_k) + reduce +
/// comm`). Both modeled fields are set to the bulk figure, and both
/// measured schedule columns to the measured wall: a bulk round tracked
/// no carry and ran no concurrency, so no overlap is claimed for it.
pub fn finish_round(
    comm: &CommModel,
    map_durations: Vec<Duration>,
    reduce_duration: Duration,
    bytes_transferred: u64,
    measured_wall: Duration,
) -> RoundStats {
    let workers = map_durations.len();
    let crit = map_durations
        .iter()
        .copied()
        .max()
        .unwrap_or_default()
        .as_secs_f64();
    let bulk = crit
        + reduce_duration.as_secs_f64()
        + comm.round_time(workers, bytes_transferred);
    let wall = measured_wall.as_secs_f64();
    RoundStats {
        map_durations,
        reduce_duration,
        bytes_transferred,
        modeled_wall_s: bulk,
        modeled_bulk_s: bulk,
        modeled_overlapped_s: bulk,
        measured_wall_s: wall,
        measured_overlapped_s: wall,
        measured_serialized_s: wall,
        retries: 0,
        watchdog_fires: 0,
        quarantined_shards: 0,
    }
}

/// Assemble a [`RoundStats`] for an **overlapped** round. `stats_bytes`
/// is the small reduced-statistics upload that stays on the critical
/// path; `carry_s` is the previous round's hidden tail (its shuffle
/// transfer time plus its global-update compute), which this round pays
/// only to the extent it exceeds the map critical path. The bulk figure
/// is computed from the same measurements so `--overlap on` runs can
/// report both schedules side by side. `timing` carries the real host
/// timings: `measured_overlapped_s` is the round's true wall, and
/// `measured_serialized_s` reconstructs what serializing the same work
/// bulk-style would have cost (map window + reduce tail).
pub fn finish_round_overlapped(
    comm: &CommModel,
    map_durations: Vec<Duration>,
    reduce_duration: Duration,
    bytes_transferred: u64,
    stats_bytes: u64,
    carry_s: f64,
    timing: OverlappedTiming,
) -> RoundStats {
    let workers = map_durations.len();
    let crit = map_durations
        .iter()
        .copied()
        .max()
        .unwrap_or_default()
        .as_secs_f64();
    let bulk = crit
        + reduce_duration.as_secs_f64()
        + comm.round_time(workers, bytes_transferred);
    let overlapped = comm.overlapped_round_time(workers, stats_bytes, crit, carry_s);
    RoundStats {
        map_durations,
        reduce_duration,
        bytes_transferred,
        modeled_wall_s: overlapped,
        modeled_bulk_s: bulk,
        modeled_overlapped_s: overlapped,
        measured_wall_s: timing.wall.as_secs_f64(),
        measured_overlapped_s: timing.wall.as_secs_f64(),
        measured_serialized_s: (timing.window + reduce_duration).as_secs_f64(),
        retries: 0,
        watchdog_fires: 0,
        quarantined_shards: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order_and_results() {
        let mr = MapReduce::new(4);
        let tasks: Vec<u64> = (0..37).collect();
        let (out, durs) = mr.map(tasks, |_, x| x * x);
        assert_eq!(out, (0..37).map(|x| x * x).collect::<Vec<_>>());
        assert_eq!(durs.len(), 37);
    }

    #[test]
    fn serial_and_parallel_agree() {
        let tasks: Vec<u64> = (0..16).collect();
        let f = |_: usize, x: u64| {
            // tiny busy-work so durations are nonzero
            let mut acc = x;
            for i in 0..1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            acc
        };
        let (a, _) = MapReduce::new(1).map(tasks.clone(), f);
        let (b, _) = MapReduce::new(3).map(tasks, f);
        assert_eq!(a, b);
    }

    #[test]
    fn pool_is_reused_across_rounds() {
        // many rounds through ONE executor: results stay correct and no
        // per-round spawn is needed (the pool threads persist)
        let mr = MapReduce::new(3);
        for round in 0..50u64 {
            let tasks: Vec<u64> = (0..7).collect();
            let (out, durs) = mr.map(tasks, |_, x| x + round);
            assert_eq!(out, (0..7).map(|x| x + round).collect::<Vec<_>>());
            assert_eq!(durs.len(), 7);
        }
    }

    #[test]
    fn borrowed_state_is_visible_to_tasks() {
        // tasks may capture caller-stack borrows (the coordinator hands
        // shards &data and &model this way)
        let shared: Vec<u64> = (0..100).collect();
        let mr = MapReduce::new(2);
        let tasks: Vec<usize> = (0..10).collect();
        let (out, _) = mr.map(tasks, |_, i| shared[i * 10]);
        assert_eq!(out, (0..10).map(|i| (i as u64) * 10).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn task_panic_propagates_with_payload() {
        // the original panic message must survive the pool boundary
        let mr = MapReduce::new(2);
        let tasks: Vec<u64> = (0..4).collect();
        let _ = mr.map(tasks, |_, x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn empty_task_list() {
        let mr = MapReduce::new(2);
        let (out, durs) = mr.map(Vec::<u32>::new(), |_, x| x);
        assert!(out.is_empty() && durs.is_empty());
    }

    #[test]
    fn comm_model_costs_scale() {
        let c = CommModel {
            round_latency_s: 1.0,
            per_worker_latency_s: 0.1,
            bandwidth_bytes_per_s: 1000.0,
        };
        let t = c.round_time(10, 5000);
        assert!((t - (1.0 + 1.0 + 5.0)).abs() < 1e-12);
        assert_eq!(CommModel::free().round_time(128, u64::MAX), 0.0);
    }

    #[test]
    fn round_stats_critical_path() {
        let durs = vec![
            Duration::from_millis(5),
            Duration::from_millis(20),
            Duration::from_millis(10),
        ];
        let rs = finish_round(
            &CommModel::free(),
            durs,
            Duration::from_millis(2),
            0,
            Duration::from_millis(40),
        );
        assert_eq!(rs.map_critical_path(), Duration::from_millis(20));
        assert_eq!(rs.map_total(), Duration::from_millis(35));
        assert!((rs.modeled_wall_s - 0.022).abs() < 1e-9);
        // a bulk round claims no overlap: both schedule fields pin to
        // the serialized figure, and both measured columns to the wall
        assert_eq!(rs.modeled_bulk_s, rs.modeled_wall_s);
        assert_eq!(rs.modeled_overlapped_s, rs.modeled_wall_s);
        assert_eq!(rs.measured_overlapped_s, rs.measured_wall_s);
        assert_eq!(rs.measured_serialized_s, rs.measured_wall_s);
    }

    #[test]
    fn overlapped_round_time_takes_max_of_map_and_carry() {
        let c = CommModel {
            round_latency_s: 1.0,
            per_worker_latency_s: 0.1,
            bandwidth_bytes_per_s: 1000.0,
        };
        // fixed part: 1.0 + 2*0.1 + 500/1000 = 1.7
        let slow_map = c.overlapped_round_time(2, 500, 5.0, 3.0);
        assert!((slow_map - (1.7 + 5.0)).abs() < 1e-12);
        let slow_carry = c.overlapped_round_time(2, 500, 2.0, 3.0);
        assert!((slow_carry - (1.7 + 3.0)).abs() < 1e-12);
        // no carry, free comm: overlapped == pure map critical path
        assert_eq!(CommModel::free().overlapped_round_time(8, 1 << 20, 0.25, 0.0), 0.25);
    }

    #[test]
    fn finish_round_overlapped_pins_both_schedule_formulas() {
        // the Fig. 8 contract: the SAME measurements yield the
        // serialized figure (map crit 20ms + reduce 2ms = 22ms) AND the
        // overlapped figure (max(map crit 20ms, carry 50ms) = 50ms)
        let durs = vec![
            Duration::from_millis(5),
            Duration::from_millis(20),
            Duration::from_millis(10),
        ];
        let rs = finish_round_overlapped(
            &CommModel::free(),
            durs,
            Duration::from_millis(2),
            4096,
            64,
            0.050,
            OverlappedTiming {
                wall: Duration::from_millis(40),
                window: Duration::from_millis(25),
            },
        );
        assert!((rs.modeled_bulk_s - 0.022).abs() < 1e-9);
        assert!((rs.modeled_overlapped_s - 0.050).abs() < 1e-9);
        assert_eq!(rs.modeled_wall_s, rs.modeled_overlapped_s);
        // measured columns: overlapped == real wall; serialized
        // reconstructs window + reduce tail (25ms + 2ms)
        assert!((rs.measured_overlapped_s - 0.040).abs() < 1e-9);
        assert_eq!(rs.measured_overlapped_s, rs.measured_wall_s);
        assert!((rs.measured_serialized_s - 0.027).abs() < 1e-9);
        // with the carry hidden under the map, the overlapped schedule
        // must beat bulk whenever carry < map_crit + reduce + comm
        let rs2 = finish_round_overlapped(
            &CommModel::free(),
            vec![Duration::from_millis(20)],
            Duration::from_millis(2),
            4096,
            64,
            0.010,
            OverlappedTiming {
                wall: Duration::from_millis(40),
                window: Duration::from_millis(25),
            },
        );
        assert!(rs2.modeled_overlapped_s < rs2.modeled_bulk_s);
    }

    #[test]
    fn map_collect_reports_each_completion_once_in_rank_order() {
        let mr = MapReduce::new(4);
        let tasks: Vec<u64> = (0..24).collect();
        let mut seen: Vec<(usize, usize)> = Vec::new();
        let (out, durs) = mr.map_collect(tasks, |_, x| x * 3, |rank, idx| seen.push((rank, idx)));
        // results in input order regardless of completion order
        assert_eq!(out, (0..24).map(|x| x * 3).collect::<Vec<_>>());
        assert_eq!(durs.len(), 24);
        // ranks arrive 0..n in order; indices are a permutation of 0..n
        assert_eq!(
            seen.iter().map(|&(r, _)| r).collect::<Vec<_>>(),
            (0..24).collect::<Vec<_>>()
        );
        let mut idxs: Vec<usize> = seen.iter().map(|&(_, i)| i).collect();
        idxs.sort_unstable();
        assert_eq!(idxs, (0..24).collect::<Vec<_>>());
    }

    #[test]
    fn map_streaming_accumulates_followups() {
        // every task is granted exactly two follow-ups; the result and
        // the pooled duration must account for base + both grants, on
        // both the inline and the pooled path
        for parallelism in [1usize, 4] {
            let mr = MapReduce::new(parallelism);
            let tasks: Vec<u64> = (0..12).collect();
            let mut events = 0usize;
            let (out, durs) = mr.map_streaming(
                tasks,
                |_, x| x * 10,
                |_, r| r + 1,
                |ev| {
                    events += 1;
                    ev.followups_done < 2
                },
            );
            assert_eq!(out, (0..12).map(|x| x * 10 + 2).collect::<Vec<_>>());
            assert_eq!(durs.len(), 12);
            // 12 base + 24 follow-up completions, each reacted once
            assert_eq!(events, 36);
        }
    }

    #[test]
    fn map_streaming_event_fields_are_consistent() {
        let mr = MapReduce::new(3);
        let tasks: Vec<u64> = (0..9).collect();
        let mut seen: Vec<(usize, usize, usize)> = Vec::new();
        let (_, _) = mr.map_streaming(
            tasks,
            |i, x| x + i as u64,
            |_, r| r,
            |ev| {
                seen.push((ev.rank, ev.index, ev.followups_done));
                ev.followups_done == 0 && ev.index % 3 == 0
            },
        );
        // ranks are a strict 0..len sequence
        assert_eq!(
            seen.iter().map(|&(r, _, _)| r).collect::<Vec<_>>(),
            (0..seen.len()).collect::<Vec<_>>()
        );
        // indexes 0,3,6 got exactly one follow-up event each
        for i in [0usize, 3, 6] {
            assert_eq!(
                seen.iter().filter(|&&(_, x, fu)| x == i && fu == 1).count(),
                1
            );
        }
        assert_eq!(seen.len(), 12);
    }

    #[test]
    fn delay_hook_pins_completion_order() {
        // with 4 workers and a long injected delay on task 0, every
        // other base task must complete (and react) before task 0 does —
        // the determinism lever the interleaving harness relies on
        let mut mr = MapReduce::new(4);
        mr.set_delay_hook(Some(Arc::new(|i| {
            Duration::from_millis(if i == 0 { 120 } else { 0 })
        })));
        let tasks: Vec<u64> = (0..4).collect();
        let mut order: Vec<usize> = Vec::new();
        let (out, _) = mr.map_streaming(
            tasks,
            |_, x| x,
            |_, r| r,
            |ev| {
                order.push(ev.index);
                false
            },
        );
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(order.len(), 4);
        assert_eq!(*order.last().unwrap(), 0, "delayed task finishes last");
    }

    #[test]
    #[should_panic(expected = "streaming boom")]
    fn map_streaming_panic_drains_then_propagates() {
        let mr = MapReduce::new(3);
        let tasks: Vec<u64> = (0..6).collect();
        let _ = mr.map_streaming(
            tasks,
            |_, x| {
                if x == 4 {
                    panic!("streaming boom");
                }
                x
            },
            |_, r| r,
            // grant one follow-up to everything that completes before
            // the panic lands; the drain must still terminate
            |ev| ev.followups_done == 0,
        );
    }

    #[test]
    #[should_panic(expected = "injected fault: shard 2 crashed")]
    fn fault_hook_panic_aborts_unsupervised_map() {
        // without supervision an injected Panic behaves exactly like an
        // organic shard panic: drained, then re-raised on the caller
        let mut mr = MapReduce::new(3);
        mr.set_fault_hook(Some(Arc::new(|site: FaultSite| {
            if site.task == 2 {
                FaultAction::Panic("shard 2 crashed".to_string())
            } else {
                FaultAction::None
            }
        })));
        let tasks: Vec<u64> = (0..6).collect();
        let _ = mr.map(tasks, |_, x| x);
    }

    #[test]
    fn fault_site_carries_the_round_tag() {
        let mut mr = MapReduce::new(1);
        let seen = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&seen);
        mr.set_fault_hook(Some(Arc::new(move |site: FaultSite| {
            sink.lock().unwrap().push(site);
            FaultAction::None
        })));
        mr.set_fault_round(7);
        let (out, _) = mr.map(vec![1u64, 2], |_, x| x);
        assert_eq!(out, vec![1, 2]);
        let sites = seen.lock().unwrap();
        assert_eq!(
            *sites,
            vec![
                FaultSite { round: 7, task: 0, attempt: 0 },
                FaultSite { round: 7, task: 1, attempt: 0 },
            ]
        );
    }

    #[test]
    fn map_supervised_retry_recovers_the_fault_free_result() {
        // task 1's first attempt panics, its second is let through: the
        // supervisor respawns with the original input and the final
        // results must be exactly what a fault-free run produces
        for parallelism in [1usize, 4] {
            let mut mr = MapReduce::new(parallelism);
            mr.set_fault_hook(Some(Arc::new(|site: FaultSite| {
                if site.task == 1 && site.attempt == 0 {
                    FaultAction::Panic("first attempt dies".to_string())
                } else {
                    FaultAction::None
                }
            })));
            let tasks: Vec<u64> = (0..5).collect();
            let mut failures = 0usize;
            let (out, durs) = mr.map_supervised(
                tasks,
                |_, x| x * 2,
                |_, r| r,
                None,
                |ev| match ev.outcome {
                    SupervisedOutcome::Done(_) => SupervisedDirective::Retire,
                    SupervisedOutcome::Failed(ref msg) => {
                        assert!(msg.contains("first attempt dies"), "got: {msg}");
                        failures += 1;
                        // respawn from the original input
                        SupervisedDirective::Respawn(ev.index as u64, Duration::ZERO)
                    }
                    SupervisedOutcome::TimedOut => unreachable!("no timeout set"),
                },
            );
            assert_eq!(failures, 1);
            assert_eq!(
                out,
                (0..5).map(|x| Some(x * 2)).collect::<Vec<_>>(),
                "parallelism {parallelism}"
            );
            assert_eq!(durs.len(), 5);
        }
    }

    #[test]
    fn map_supervised_abandon_after_exhausted_retries() {
        // task 3 fails every attempt with an injected I/O error; after
        // two retries the supervisor abandons it — its slot is None,
        // everything else completes normally
        for parallelism in [1usize, 4] {
            let mut mr = MapReduce::new(parallelism);
            mr.set_fault_hook(Some(Arc::new(|site: FaultSite| {
                if site.task == 3 {
                    FaultAction::Io("lost connection".to_string())
                } else {
                    FaultAction::None
                }
            })));
            let tasks: Vec<u64> = (0..6).collect();
            let (out, _) = mr.map_supervised(
                tasks,
                |_, x| x + 100,
                |_, r| r,
                None,
                |ev| match ev.outcome {
                    SupervisedOutcome::Done(_) => SupervisedDirective::Retire,
                    SupervisedOutcome::Failed(ref msg) => {
                        assert!(msg.contains("injected I/O error"), "got: {msg}");
                        if ev.attempt < 2 {
                            SupervisedDirective::Respawn(ev.index as u64, Duration::ZERO)
                        } else {
                            SupervisedDirective::Abandon
                        }
                    }
                    SupervisedOutcome::TimedOut => unreachable!("no timeout set"),
                },
            );
            for (i, slot) in out.iter().enumerate() {
                if i == 3 {
                    assert!(slot.is_none(), "parallelism {parallelism}");
                } else {
                    assert_eq!(*slot, Some(i as u64 + 100), "parallelism {parallelism}");
                }
            }
        }
    }

    #[test]
    fn map_supervised_followups_still_accumulate() {
        // the bonus-sweep surface survives supervision: grant two
        // follow-ups per task, then retire
        for parallelism in [1usize, 4] {
            let mr = MapReduce::new(parallelism);
            let tasks: Vec<u64> = (0..8).collect();
            let (out, durs) = mr.map_supervised(
                tasks,
                |_, x| x * 10,
                |_, r| r + 1,
                None,
                |ev| match ev.outcome {
                    SupervisedOutcome::Done(_) => {
                        if ev.followups_done < 2 {
                            SupervisedDirective::Follow
                        } else {
                            SupervisedDirective::Retire
                        }
                    }
                    _ => SupervisedDirective::Abandon,
                },
            );
            assert_eq!(
                out,
                (0..8).map(|x| Some(x * 10 + 2)).collect::<Vec<_>>(),
                "parallelism {parallelism}"
            );
            assert_eq!(durs.len(), 8);
        }
    }

    #[test]
    fn map_supervised_watchdog_supersedes_a_stalled_attempt() {
        // task 0's first attempt stalls far past the watchdog deadline;
        // the timeout event respawns it and the respawned attempt's
        // result wins. The stalled attempt eventually completes too —
        // its stale completion must be discarded, not double-reported.
        let mut mr = MapReduce::new(4);
        mr.set_fault_hook(Some(Arc::new(|site: FaultSite| {
            if site.task == 0 && site.attempt == 0 {
                FaultAction::Stall(Duration::from_millis(400))
            } else {
                FaultAction::None
            }
        })));
        let tasks: Vec<u64> = (0..4).collect();
        let mut timeouts = 0usize;
        let mut done_events_task0 = 0usize;
        let (out, _) = mr.map_supervised(
            tasks,
            |_, x| x + 7,
            |_, r| r,
            Some(Duration::from_millis(60)),
            |ev| match ev.outcome {
                SupervisedOutcome::Done(_) => {
                    if ev.index == 0 {
                        done_events_task0 += 1;
                    }
                    SupervisedDirective::Retire
                }
                SupervisedOutcome::TimedOut => {
                    // on a quiet machine only the stalled task 0 gets
                    // here, but a loaded CI box may time out others too;
                    // respawning them is always safe
                    timeouts += 1;
                    SupervisedDirective::Respawn(ev.index as u64, Duration::ZERO)
                }
                SupervisedOutcome::Failed(ref msg) => panic!("unexpected failure: {msg}"),
            },
        );
        assert!(timeouts >= 1, "the watchdog must have fired");
        assert_eq!(done_events_task0, 1, "stale completion must be discarded");
        assert_eq!(out, vec![Some(7), Some(8), Some(9), Some(10)]);
    }

    #[test]
    fn supervised_round_stats_counters_default_to_zero() {
        let rs = finish_round(
            &CommModel::free(),
            vec![Duration::from_millis(1)],
            Duration::ZERO,
            0,
            Duration::from_millis(1),
        );
        assert_eq!((rs.retries, rs.watchdog_fires, rs.quarantined_shards), (0, 0, 0));
    }

    #[test]
    fn more_workers_raise_comm_but_cut_critical_path() {
        // the Fig. 8 mechanism in miniature: total work W split over K
        // workers has modeled time W/K + comm(K); check the tradeoff turns
        let comm = CommModel {
            round_latency_s: 0.5,
            per_worker_latency_s: 0.2,
            bandwidth_bytes_per_s: f64::INFINITY,
        };
        let total_work = 10.0;
        let modeled = |k: usize| total_work / k as f64 + comm.round_time(k, 0);
        assert!(modeled(4) < modeled(1));
        assert!(modeled(64) > modeled(8), "saturation must kick in");
    }
}
