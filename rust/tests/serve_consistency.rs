//! Snapshot-consistency gate for the serving layer (`rust/src/serve/`).
//!
//! The serving contract under test: every response is computed from a
//! snapshot published at a **round boundary** of the background chain —
//! an exact posterior sample — and is **bit-equal** to offline scoring
//! against that round's exported tables. The offline reference is an
//! independent chain run with the same seed and config in this test
//! process: because snapshot export consumes no randomness, a read-only
//! serve driver consumes exactly the offline chain's master-RNG draw
//! sequence, so round r's published tables are bit-identical to the
//! offline replica's round-r export.
//!
//! The hammer runs while the background chain refines under an injected
//! per-task `DelayHook` stall **plus** a `FaultHook` panic handled by
//! PR 9's supervised-recovery ladder — responses must stay bit-exact
//! (supervised recovery is bit-transparent) and serving must never
//! drop.
//!
//! Also gated here, per the acceptance list:
//! * kill + restart auto-resumes from the `CheckpointDir` ring and
//!   serves again;
//! * `--serve-trace` emits parseable JSONL with p50/p99 and queries/sec
//!   columns;
//! * online INSERT/DELETE fold in at a round boundary and show up in
//!   STATS.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use clustercluster::coordinator::{Coordinator, CoordinatorConfig, SuperviseConfig};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::data::BinMat;
use clustercluster::mapreduce::{DelayHook, FaultAction, FaultHook, FaultSite};
use clustercluster::rng::Pcg64;
use clustercluster::runtime::FallbackScorer;
use clustercluster::sampler::TableSet;
use clustercluster::serve::protocol::{Request, Response, RowBits};
use clustercluster::serve::{spawn, spawn_with_hooks, Client, ServeConfig};
use clustercluster::special::logsumexp;
use clustercluster::util::json;

const WAIT_CAP: Duration = Duration::from_secs(120);

fn make_data(seed: u64) -> BinMat {
    SyntheticConfig {
        n: 60,
        d: 16,
        clusters: 4,
        beta: 0.2,
        seed,
    }
    .generate()
    .train
}

fn base_cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        workers: 3,
        ..Default::default()
    }
}

fn supervised() -> SuperviseConfig {
    SuperviseConfig {
        enabled: true,
        max_retries: 2,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(4),
        ..Default::default()
    }
}

fn temp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join("cc_serve")
        .join(format!("{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("create temp dir");
    d
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < WAIT_CAP, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// One offline round-boundary export: what the server's published
/// snapshot for that round must be bit-identical to.
struct OfflineSnap {
    alpha: f64,
    log_pred_empty: f64,
    tables: TableSet,
}

/// Replay the serve driver's exact chain offline (same seed, same
/// config, no faults) and export the tables at every round boundary.
fn offline_replica(
    data: &BinMat,
    ccfg: &CoordinatorConfig,
    seed: u64,
    rounds: u64,
) -> HashMap<u64, OfflineSnap> {
    let mut rng = Pcg64::seed_from(seed);
    let mut coord = Coordinator::new(data, ccfg.clone(), &mut rng);
    let log_pred_empty = coord.model.as_bernoulli().empty_cluster_loglik();
    let mut snaps = HashMap::new();
    snaps.insert(
        coord.rounds,
        OfflineSnap {
            alpha: coord.alpha,
            log_pred_empty,
            tables: coord.export_table_set(),
        },
    );
    for _ in 0..rounds {
        coord.step(&mut rng);
        snaps.insert(
            coord.rounds,
            OfflineSnap {
                alpha: coord.alpha,
                log_pred_empty,
                tables: coord.export_table_set(),
            },
        );
    }
    snaps
}

/// Offline scores of one wire row against one round's tables, through
/// the identical code path the server uses (`TableSet::score_rows` via
/// the pure-Rust scorer).
fn offline_scores(snap: &OfflineSnap, row: &RowBits) -> Vec<f64> {
    let m = row.to_binmat();
    let mut scorer = FallbackScorer::new();
    let mut out = Vec::new();
    snap.tables.score_rows(&mut scorer, &m, &[0], &mut out);
    out
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// the hammer

#[test]
fn concurrent_reads_are_bit_equal_to_offline_round_snapshots() {
    const SEED: u64 = 0xC0;
    const ROUNDS: u64 = 6;
    let data = make_data(7);
    let ccfg = CoordinatorConfig {
        supervise: supervised(),
        ..base_cfg()
    };

    // injected adversity: every map task stalls 20ms (so the hammer
    // provably overlaps in-flight sweeps), and round 2 / task 0 panics
    // on its first attempt (PR 9 supervised recovery must be
    // bit-transparent and must not drop serving)
    let delay: DelayHook = Arc::new(|_task| Duration::from_millis(20));
    let fault: FaultHook = Arc::new(|site: FaultSite| {
        if site.round == 2 && site.task == 0 && site.attempt == 0 {
            FaultAction::Panic("injected serve fault".to_string())
        } else {
            FaultAction::None
        }
    });

    let scfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        rounds: ROUNDS,
        seed: SEED,
        ..Default::default()
    };
    let server = spawn_with_hooks(data.clone(), ccfg.clone(), scfg, Some(delay), Some(fault))
        .expect("spawn server");
    let addr = server.addr().to_string();

    // hammer score/assign/density over every data row while the chain
    // refines, recording (request row, response) pairs for post-hoc
    // bit-exact verification
    let mut c = Client::connect(&addr).expect("connect");
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut observed: Vec<(RowBits, Response)> = Vec::new();
    let mut pass = 0usize;
    loop {
        let done_before_pass = server.refinement_done();
        for r in 0..data.rows() {
            let row = RowBits::from_binmat(&data, r);
            let req = match (pass + r) % 3 {
                0 => Request::Score(row.clone()),
                1 => Request::Assign(row.clone()),
                _ => Request::Density(row.clone()),
            };
            let resp = c.request(&req).expect("query");
            observed.push((row, resp));
        }
        pass += 1;
        // one full pass after the budget is exhausted pins the final
        // round's snapshot too
        if done_before_pass {
            break;
        }
    }
    server.join().expect("clean shutdown");

    // offline replica of the identical chain
    let snaps = offline_replica(&data, &ccfg, SEED, ROUNDS);

    let mut rounds_seen = std::collections::BTreeSet::new();
    for (row, resp) in &observed {
        match resp {
            Response::Score(b) => {
                let snap = snaps.get(&b.round).unwrap_or_else(|| {
                    panic!("response claims unpublished round {}", b.round)
                });
                rounds_seen.insert(b.round);
                assert_eq!(
                    b.log_pred_empty.to_bits(),
                    snap.log_pred_empty.to_bits(),
                    "log_pred_empty mismatch at round {}",
                    b.round
                );
                assert_eq!(
                    bits(&b.scores),
                    bits(&offline_scores(snap, row)),
                    "score block not bit-equal at round {}",
                    b.round
                );
            }
            Response::Assign(b) => {
                let snap = snaps.get(&b.round).unwrap_or_else(|| {
                    panic!("response claims unpublished round {}", b.round)
                });
                rounds_seen.insert(b.round);
                // replicate the server's deterministic MAP fold exactly
                let scores = offline_scores(snap, row);
                let logn = snap.tables.logn();
                let mut cluster = -1i64;
                let mut w = snap.alpha.ln() + snap.log_pred_empty;
                for (i, &sc) in scores.iter().enumerate() {
                    let wi = logn[i] + sc;
                    if wi > w {
                        w = wi;
                        cluster = i as i64;
                    }
                }
                assert_eq!(b.cluster, cluster, "MAP cluster mismatch at round {}", b.round);
                assert_eq!(
                    b.log_weight.to_bits(),
                    w.to_bits(),
                    "MAP weight not bit-equal at round {}",
                    b.round
                );
            }
            Response::Density(b) => {
                let snap = snaps.get(&b.round).unwrap_or_else(|| {
                    panic!("response claims unpublished round {}", b.round)
                });
                rounds_seen.insert(b.round);
                let scores = offline_scores(snap, row);
                let logn = snap.tables.logn();
                let mut terms: Vec<f64> = scores
                    .iter()
                    .enumerate()
                    .map(|(i, &sc)| logn[i] + sc)
                    .collect();
                terms.push(snap.alpha.ln() + snap.log_pred_empty);
                let want = logsumexp(&terms) - (data.rows() as f64 + snap.alpha).ln();
                assert_eq!(
                    b.log_density.to_bits(),
                    want.to_bits(),
                    "density not bit-equal at round {}",
                    b.round
                );
            }
            other => panic!("unexpected response in hammer: {other:?}"),
        }
    }
    // the chain refined under the hammer: snapshots from more than one
    // round boundary must have answered (the 20ms/task stall guarantees
    // queries land both early and late)
    assert!(
        rounds_seen.len() >= 2,
        "expected responses from >= 2 distinct round snapshots, got {rounds_seen:?}"
    );
    assert!(
        rounds_seen.contains(&ROUNDS),
        "final-round snapshot never answered: {rounds_seen:?}"
    );
}

// ---------------------------------------------------------------------------
// durability: restart from the checkpoint ring

#[test]
fn restart_auto_resumes_from_checkpoint_ring_and_serves_again() {
    const SEED: u64 = 0xD1;
    const ROUNDS: u64 = 4;
    let dir = temp_dir("restart");
    let data = make_data(9);
    let mk_scfg = || ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        rounds: ROUNDS,
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 2,
        checkpoint_keep: 3,
        seed: SEED,
        ..Default::default()
    };

    // first life: refine to the budget, stop (final generation saved)
    let a = spawn(data.clone(), base_cfg(), mk_scfg()).expect("spawn first server");
    wait_until("first server to finish refining", || a.refinement_done());
    a.join().expect("first server clean shutdown");
    let gens: Vec<_> = std::fs::read_dir(&dir)
        .expect("read ring dir")
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().ends_with(".ccckpt"))
        .collect();
    assert!(
        !gens.is_empty(),
        "checkpoint ring is empty after a checkpointed serve run"
    );

    // second life: must auto-resume at the saved round (a fresh chain
    // would publish round 0 first) and serve queries again
    let b = spawn(data.clone(), base_cfg(), mk_scfg()).expect("respawn server");
    let snap = b.snapshot().expect("published snapshot after resume");
    assert_eq!(
        snap.round, ROUNDS,
        "server did not resume from the checkpoint ring"
    );
    let mut c = Client::connect(b.addr()).expect("connect");
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();
    match c.request(&Request::Stats).expect("stats") {
        Response::Stats(s) => {
            assert_eq!(s.round, ROUNDS);
            assert_eq!(s.rows, data.rows() as u64);
        }
        other => panic!("expected Stats, got {other:?}"),
    }
    let row = RowBits::from_binmat(&data, 0);
    match c.request(&Request::Score(row)).expect("score after resume") {
        Response::Score(s) => assert_eq!(s.round, ROUNDS),
        other => panic!("expected Score, got {other:?}"),
    }
    match c.request(&Request::Shutdown).expect("shutdown") {
        Response::ShuttingDown => {}
        other => panic!("expected ShuttingDown, got {other:?}"),
    }
    b.join().expect("second server clean shutdown");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// observability: the --serve-trace JSONL

#[test]
fn serve_trace_emits_parseable_latency_columns() {
    const SEED: u64 = 0xE2;
    let dir = temp_dir("trace");
    let trace = dir.join("serve.jsonl");
    let data = make_data(3);
    let scfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        rounds: 3,
        trace_path: Some(trace.clone()),
        trace_every: 1,
        seed: SEED,
        ..Default::default()
    };
    let server = spawn(data.clone(), base_cfg(), scfg).expect("spawn server");
    let mut c = Client::connect(server.addr()).expect("connect");
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();
    for r in 0..data.rows().min(20) {
        let row = RowBits::from_binmat(&data, r);
        c.request(&Request::Score(row)).expect("score");
        c.request(&Request::Ping).expect("ping");
    }
    wait_until("refinement to finish", || server.refinement_done());
    drop(c);
    server.join().expect("clean shutdown");

    let text = std::fs::read_to_string(&trace).expect("read trace file");
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(
        !lines.is_empty(),
        "trace file has no records despite trace_every=1"
    );
    for line in &lines {
        let j = json::parse(line).unwrap_or_else(|e| panic!("bad trace JSON {line:?}: {e}"));
        for key in [
            "rounds_refined",
            "elapsed_s",
            "queries",
            "qps",
            "ping_count",
            "ping_p50_us",
            "ping_p99_us",
            "score_count",
            "score_p50_us",
            "score_p99_us",
            "assign_p50_us",
            "density_p99_us",
        ] {
            assert!(
                j.get(key).and_then(|v| v.as_f64()).is_some(),
                "trace record missing numeric column {key}: {line}"
            );
        }
    }
    // the final (shutdown) record saw the full refinement and our load
    let last = json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(last.get("rounds_refined").unwrap().as_f64().unwrap(), 3.0);
    assert!(last.get("score_count").unwrap().as_f64().unwrap() >= 1.0);
    assert!(last.get("ping_count").unwrap().as_f64().unwrap() >= 1.0);
    assert!(last.get("qps").unwrap().as_f64().unwrap() > 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// online row edits fold in at round boundaries

#[test]
fn insert_and_delete_fold_in_at_round_boundaries() {
    const SEED: u64 = 0xF3;
    let data = make_data(5);
    let n0 = data.rows() as u64;
    let scfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        rounds: 0, // keep refining so edits always reach a boundary
        seed: SEED,
        ..Default::default()
    };
    let server = spawn(data.clone(), base_cfg(), scfg).expect("spawn server");
    let mut c = Client::connect(server.addr()).expect("connect");
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();

    let stats = |c: &mut Client| match c.request(&Request::Stats).expect("stats") {
        Response::Stats(s) => s,
        other => panic!("expected Stats, got {other:?}"),
    };

    // queue an insert; provisional id = current row count
    let new_row = RowBits::from_ones(data.dims() as u32, &[1, 3, 8]);
    match c.request(&Request::Insert(new_row)).expect("insert") {
        Response::Queued { row, .. } => assert_eq!(row, n0),
        other => panic!("expected Queued, got {other:?}"),
    }
    wait_until("insert to fold in", || stats(&mut c).rows == n0 + 1);

    // the inserted row scores like any other
    let snap_dims = stats(&mut c).dims;
    let probe = RowBits::from_ones(snap_dims, &[1, 3, 8]);
    match c.request(&Request::Score(probe)).expect("score after insert") {
        Response::Score(s) => assert!(!s.scores.is_empty()),
        other => panic!("expected Score, got {other:?}"),
    }

    // delete it again
    match c.request(&Request::Delete(n0)).expect("delete") {
        Response::Queued { row, .. } => assert_eq!(row, n0),
        other => panic!("expected Queued, got {other:?}"),
    }
    wait_until("delete to fold in", || stats(&mut c).rows == n0);

    match c.request(&Request::Shutdown).expect("shutdown") {
        Response::ShuttingDown => {}
        other => panic!("expected ShuttingDown, got {other:?}"),
    }
    server.join().expect("clean shutdown");
}
