//! The barrier-free round schedule (`--overlap on`): work-stealing
//! grant planning, per-shard idle/barrier-wait/bonus observability, the
//! dual modeled-wall bookkeeping, and state integrity across overlapped
//! rounds. The statistical exactness of the schedule is gated separately
//! by the 203-partition suites in `rust/tests/posterior_exactness.rs`
//! (overlap-on variants) and the K=1 bit-equivalence in
//! `rust/tests/k1_equivalence.rs`.

use clustercluster::coordinator::{
    plan_bonus_sweeps, Coordinator, CoordinatorConfig,
};
use clustercluster::mapreduce::CommModel;
use clustercluster::rng::Pcg64;
use clustercluster::testing::enumeration_fixture;

fn overlap_cfg(workers: usize, max_bonus_sweeps: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        update_alpha: false,
        update_beta: false,
        comm: CommModel::free(),
        parallelism: 1,
        overlap: true,
        max_bonus_sweeps,
        ..Default::default()
    }
}

#[test]
fn bonus_plan_is_deterministic_bounded_and_balanced_aware() {
    // grant ≈ how many extra sweeps fit while the heaviest shard runs,
    // capped; heaviest and empty shards always 0
    assert_eq!(plan_bonus_sweeps(&[100, 50, 20], 8), vec![0, 1, 4]);
    // the cap binds
    assert_eq!(plan_bonus_sweeps(&[100, 50, 20], 2), vec![0, 1, 2]);
    // balanced loads ⇒ no stealing anywhere
    assert_eq!(plan_bonus_sweeps(&[40, 40, 40], 5), vec![0, 0, 0]);
    // K=1 degenerates to the base schedule
    assert_eq!(plan_bonus_sweeps(&[120], 5), vec![0]);
    // empty shards get nothing (no data to sweep), ties with the max
    // get nothing, and sub-1 gaps round down to nothing
    assert_eq!(plan_bonus_sweeps(&[10, 10, 0, 7], 5), vec![0, 0, 0, 0]);
    // zero cap disables stealing outright
    assert_eq!(plan_bonus_sweeps(&[100, 1], 0), vec![0, 0]);
    assert_eq!(plan_bonus_sweeps(&[], 3), Vec::<usize>::new());
}

#[test]
fn overlapped_rounds_keep_state_integrity_and_grant_bonus_sweeps() {
    // the 6-row enumeration fixture shards unevenly almost every round
    // at K=3, so over 200 rounds the work-stealing path fires for sure
    let data = enumeration_fixture();
    let mut rng = Pcg64::seed_from(91);
    let mut coord = Coordinator::new(&data, overlap_cfg(3, 2), &mut rng);
    for _ in 0..200 {
        let rs = coord.step(&mut rng);
        // the overlapped schedule is the one reported as the round wall
        assert_eq!(rs.modeled_wall_s, rs.modeled_overlapped_s);
        assert!(rs.modeled_bulk_s.is_finite() && rs.modeled_bulk_s >= 0.0);
        // the measured columns are REAL host timings: the concurrent
        // round's wall, and the reconstructed serialized cost (window +
        // staging + post-window tail), both strictly positive
        assert!((rs.measured_overlapped_s - rs.measured_wall_s).abs() < 1e-12);
        assert!(rs.measured_overlapped_s > 0.0);
        assert!(rs.measured_serialized_s > 0.0);
        coord.check_invariants().unwrap();
    }
    let granted: u64 = coord.states().iter().map(|s| s.bonus_sweeps()).sum();
    assert!(
        granted > 0,
        "200 overlapped rounds on an unevenly sharded fixture granted no bonus sweeps"
    );
    // per-shard observability columns are populated and consistent
    for s in coord.shard_stats() {
        assert!(s.idle_s >= 0.0);
        // the barrier tax includes the idle the bonus work absorbed
        assert!(s.barrier_wait_s >= s.idle_s - 1e-12);
        assert!(s.bonus_sweeps <= 2, "cap violated: {}", s.bonus_sweeps);
    }
}

#[test]
fn bulk_rounds_report_zero_bonus_and_equal_waits() {
    let data = enumeration_fixture();
    let cfg = CoordinatorConfig {
        overlap: false,
        ..overlap_cfg(3, 2)
    };
    let mut rng = Pcg64::seed_from(92);
    let mut coord = Coordinator::new(&data, cfg, &mut rng);
    for _ in 0..20 {
        let rs = coord.step(&mut rng);
        // a bulk round claims no overlap: both modeled fields pin to
        // the serialized figure, and both measured schedule columns to
        // the measured wall
        assert_eq!(rs.modeled_wall_s, rs.modeled_bulk_s);
        assert_eq!(rs.modeled_wall_s, rs.modeled_overlapped_s);
        assert_eq!(rs.measured_overlapped_s, rs.measured_wall_s);
        assert_eq!(rs.measured_serialized_s, rs.measured_wall_s);
    }
    for s in coord.shard_stats() {
        assert_eq!(s.bonus_sweeps, 0);
        assert!((s.idle_s - s.barrier_wait_s).abs() < 1e-15);
    }
    let granted: u64 = coord.states().iter().map(|st| st.bonus_sweeps()).sum();
    assert_eq!(granted, 0, "bulk rounds must never steal work");
}

#[test]
fn k1_overlap_round_ships_only_the_cluster_count() {
    // same contract as the bulk K=1 round: no shuffle, no μ broadcast —
    // only the J_k integer crosses the (modeled) wire
    let data = enumeration_fixture();
    let mut rng = Pcg64::seed_from(93);
    let mut coord = Coordinator::new(&data, overlap_cfg(1, 3), &mut rng);
    let rs = coord.step(&mut rng);
    assert_eq!(rs.bytes_transferred, 8, "bytes = {}", rs.bytes_transferred);
    assert_eq!(coord.states()[0].bonus_sweeps(), 0);
}

#[test]
fn overlapped_modeled_wall_excludes_shuffle_bytes_from_the_round() {
    // first overlapped round, carry = 0: the modeled wall must be
    // exactly latency + per-worker setup + stats_upload/bw + map_crit —
    // the shuffle movers' bytes ride behind the NEXT round's map and
    // must NOT appear in this round's critical path (they DO appear in
    // the bulk figure computed from the same measurements)
    let data = enumeration_fixture();
    let comm = CommModel {
        round_latency_s: 0.5,
        per_worker_latency_s: 0.01,
        bandwidth_bytes_per_s: 1e6,
    };
    let cfg = CoordinatorConfig {
        comm,
        ..overlap_cfg(3, 2)
    };
    let mut rng = Pcg64::seed_from(94);
    let mut coord = Coordinator::new(&data, cfg, &mut rng);
    let rs = coord.step(&mut rng);
    let stats_bytes = rs.bytes_transferred - coord.last_shuffle_bytes();
    let map_crit = rs.map_critical_path().as_secs_f64();
    let want_overlapped = comm.overlapped_round_time(3, stats_bytes, map_crit, 0.0);
    assert!(
        (rs.modeled_overlapped_s - want_overlapped).abs() < 1e-12,
        "got {}, want {}",
        rs.modeled_overlapped_s,
        want_overlapped
    );
    // and the bulk figure from the same round serializes everything,
    // shuffle bytes and global-update compute included
    let want_bulk = map_crit
        + rs.reduce_duration.as_secs_f64()
        + comm.round_time(3, rs.bytes_transferred);
    assert!(
        (rs.modeled_bulk_s - want_bulk).abs() < 1e-12,
        "got {}, want {}",
        rs.modeled_bulk_s,
        want_bulk
    );
}
