//! The fault-tolerance gate for supervised coordinator rounds
//! (`--supervise on`; DESIGN.md §12), built on the deterministic
//! fault-injection layer ([`FaultHook`]) the same way
//! `concurrent_rounds.rs` builds on [`DelayHook`].
//!
//! Four gates:
//!
//! 1. **bit-exact recovery** — an injected panic or I/O fault at every
//!    tested (round, shard) position (the full matrix under
//!    `CC_FAULT_SWEEP=all`, a structured subset by default), across
//!    bulk/overlapped × inline/pooled schedules, leaves the final chain
//!    state bit-identical to the fault-free run at the same seed; a
//!    supervised fault-free run is itself bit-identical to
//!    `--supervise off`.
//! 2. **watchdog** — a stalled attempt trips `round_timeout`, is
//!    rebuilt from its pre-round snapshot, and the replay is bit-exact
//!    (so a *spurious* watchdog fire on a loaded CI box is harmless by
//!    the same argument — the assertions below never depend on timing).
//! 3. **quarantine exactness** — a shard whose attempts fail
//!    permanently is degraded every round (sweeps skipped, statistics
//!    still reduced, clusters still shuffled), and the chain still
//!    passes the 203-partition posterior-enumeration gate (TV < 0.05).
//! 4. **durability** — a torn generation in a `--checkpoint-dir` ring
//!    is skipped by auto-resume, which recovers the newest valid
//!    generation and continues the chain.

use clustercluster::coordinator::{
    Checkpoint, CheckpointDir, Coordinator, CoordinatorConfig, MuMode, ShuffleMove,
    SuperviseConfig,
};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::mapreduce::{CommModel, FaultAction, FaultHook, FaultSite};
use clustercluster::model::Model;
use clustercluster::rng::Pcg64;
use clustercluster::testing::{
    canonical_partition as canonical, enumerate_posterior, enumeration_fixture,
    partition_tv_distance as tv_distance, ENUM_D as D,
};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

const ROUNDS: u64 = 6;
const WORKERS: usize = 4;

/// A hook that injects `action` on the base attempt at one
/// (round, shard) position and is silent everywhere else.
fn fault_once(round: u64, shard: usize, action: FaultAction) -> FaultHook {
    Arc::new(move |site: FaultSite| {
        if site.round == round && site.task == shard && site.attempt == 0 {
            action.clone()
        } else {
            FaultAction::None
        }
    })
}

/// A hook that fails the first `attempts` attempts at one
/// (round, shard) position — exercises consecutive retries.
fn fault_attempts(round: u64, shard: usize, attempts: u32, action: FaultAction) -> FaultHook {
    Arc::new(move |site: FaultSite| {
        if site.round == round && site.task == shard && site.attempt < attempts {
            action.clone()
        } else {
            FaultAction::None
        }
    })
}

/// The (round, shard) fault positions the default CI run exercises:
/// first/last round, every shard somewhere, early and late rounds.
/// `CC_FAULT_SWEEP=all` expands to the full ROUNDS × WORKERS matrix
/// (the release/exhaustive gate, mirroring `CC_PERM_SWEEP`).
fn exercised_positions() -> Vec<(u64, usize)> {
    if std::env::var("CC_FAULT_SWEEP").map(|v| v == "all").unwrap_or(false) {
        return (0..ROUNDS)
            .flat_map(|r| (0..WORKERS).map(move |s| (r, s)))
            .collect();
    }
    vec![(0, 0), (0, 3), (2, 1), (3, 2), (5, 0), (5, 3)]
}

/// Everything recovery-exactness must hold over: the partition, the α
/// and μ bit patterns, and the final round's shuffle-decision sequence.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    partition: Vec<u8>,
    alpha_bits: u64,
    mu_bits: Vec<u64>,
    moves: Vec<ShuffleMove>,
}

/// Chain fingerprint plus the supervision observables accumulated over
/// the run (the counters are NOT part of recovery-equality — a faulted
/// run legitimately reports retries the clean run does not).
struct RunOut {
    fp: Fingerprint,
    retries: u64,
    watchdog_fires: u64,
    quarantine_events: u64,
}

fn supervised() -> SuperviseConfig {
    SuperviseConfig {
        enabled: true,
        max_retries: 2,
        // near-zero backoff keeps the fault matrix fast; the backoff
        // sleeps on the pool side and cannot touch chain state
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(4),
        round_timeout: None,
        cooldown_rounds: 2,
    }
}

/// One fixed-seed K=4 run with every global update live (α, griddy β,
/// size-proportional μ) under the given schedule, supervision policy,
/// and fault hook — the same fixture `concurrent_rounds.rs` pins.
fn run_k4(
    parallelism: usize,
    overlap: bool,
    supervise: SuperviseConfig,
    hook: Option<FaultHook>,
) -> RunOut {
    let ds = SyntheticConfig {
        n: 96,
        d: 8,
        clusters: 3,
        beta: 0.2,
        seed: 7,
    }
    .generate_with_test_fraction(0.0);
    let cfg = CoordinatorConfig {
        workers: WORKERS,
        update_alpha: true,
        update_beta: true,
        mu_mode: MuMode::SizeProportional,
        comm: CommModel::free(),
        parallelism,
        overlap,
        max_bonus_sweeps: 2,
        supervise,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(4242);
    let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
    coord.set_map_fault_hook(hook);
    let (mut retries, mut watchdog_fires) = (0u64, 0u64);
    for _ in 0..ROUNDS {
        let rs = coord.step(&mut rng);
        retries += rs.retries;
        watchdog_fires += rs.watchdog_fires;
        coord.check_invariants().unwrap();
    }
    RunOut {
        fp: Fingerprint {
            partition: canonical(&coord.assignments()),
            alpha_bits: coord.alpha().to_bits(),
            mu_bits: coord.mu().iter().map(|m| m.to_bits()).collect(),
            moves: coord.last_shuffle_moves().to_vec(),
        },
        retries,
        watchdog_fires,
        quarantine_events: coord.quarantine_events(),
    }
}

#[test]
fn supervised_rounds_without_faults_match_legacy_bit_exactly() {
    // `--supervise on` with no faults must not perturb the chain:
    // snapshots are taken but never restored, the master stream is
    // untouched inside the window, and no extra randomness is consumed
    for &(parallelism, overlap) in &[(1usize, false), (4, false), (1, true), (4, true)] {
        let legacy = run_k4(parallelism, overlap, SuperviseConfig::default(), None);
        let sup = run_k4(parallelism, overlap, supervised(), None);
        assert_eq!(
            legacy.fp,
            sup.fp,
            "supervise on (no faults) diverged from legacy at parallelism \
             {parallelism} overlap {overlap}"
        );
        assert_eq!(sup.retries, 0);
        assert_eq!(sup.watchdog_fires, 0);
        assert_eq!(sup.quarantine_events, 0);
    }
}

#[test]
fn injected_faults_recover_bit_exactly_at_every_position() {
    // gate 1: a panic or I/O fault at any (round, shard) position is
    // retried from the pre-round snapshot, and because the rebuilt
    // shard replays the identical private RNG stream, the final chain
    // state is bit-identical to the fault-free run
    for &(parallelism, overlap) in &[(1usize, false), (4, false), (1, true), (4, true)] {
        let reference = run_k4(parallelism, overlap, supervised(), None);
        for (round, shard) in exercised_positions() {
            for action in [
                FaultAction::Panic(format!("injected r{round} s{shard}")),
                FaultAction::Io(format!("injected r{round} s{shard}")),
            ] {
                let label = format!(
                    "{action:?} at (round {round}, shard {shard}), parallelism \
                     {parallelism}, overlap {overlap}"
                );
                let faulted = run_k4(
                    parallelism,
                    overlap,
                    supervised(),
                    Some(fault_once(round, shard, action)),
                );
                assert_eq!(reference.fp, faulted.fp, "{label} perturbed the chain");
                assert_eq!(faulted.retries, 1, "{label}: expected exactly one retry");
                assert_eq!(faulted.quarantine_events, 0, "{label}: must not quarantine");
            }
        }
    }
}

#[test]
fn consecutive_failures_within_the_retry_budget_recover_bit_exactly() {
    // both the first attempt AND its first retry fail; the second retry
    // (attempt 2, within max_retries = 2) succeeds and replays clean
    let reference = run_k4(4, true, supervised(), None);
    let faulted = run_k4(
        4,
        true,
        supervised(),
        Some(fault_attempts(2, 1, 2, FaultAction::Panic("double".into()))),
    );
    assert_eq!(reference.fp, faulted.fp, "double failure perturbed the chain");
    assert_eq!(faulted.retries, 2);
    assert_eq!(faulted.quarantine_events, 0);
}

#[test]
fn watchdog_rescues_a_stalled_attempt_bit_exactly() {
    // gate 2: shard 1's base attempt at round 1 stalls far past the
    // round timeout; the watchdog declares it dead, the respawned
    // attempt replays from the snapshot, and the stale completion is
    // discarded — bit-exact recovery, same as a panic. (If a slow CI
    // box trips the watchdog on OTHER shards too, those replays are
    // bit-exact by the same argument, so the equality still holds.)
    let sup = SuperviseConfig {
        round_timeout: Some(Duration::from_millis(150)),
        backoff_base: Duration::ZERO,
        backoff_cap: Duration::ZERO,
        ..supervised()
    };
    let reference = run_k4(4, false, supervised(), None);
    let stalled = run_k4(
        4,
        false,
        sup,
        Some(fault_once(1, 1, FaultAction::Stall(Duration::from_millis(900)))),
    );
    assert_eq!(reference.fp, stalled.fp, "stall recovery perturbed the chain");
    assert!(stalled.watchdog_fires >= 1, "the injected stall never tripped the watchdog");
}

#[test]
fn exhausted_retries_quarantine_then_reintegrate() {
    // a shard whose attempts all fail during one round burns its
    // retries, degrades (zero-sweep attempt — here that fails too, so
    // the post-window fixup restores the snapshot), sits out the
    // cool-down quarantined, then reintegrates automatically
    let ds = SyntheticConfig {
        n: 96,
        d: 8,
        clusters: 3,
        beta: 0.2,
        seed: 7,
    }
    .generate_with_test_fraction(0.0);
    let cfg = CoordinatorConfig {
        workers: WORKERS,
        update_alpha: true,
        comm: CommModel::free(),
        parallelism: 4,
        supervise: SuperviseConfig {
            backoff_base: Duration::ZERO,
            backoff_cap: Duration::ZERO,
            cooldown_rounds: 2,
            ..supervised()
        },
        ..Default::default()
    };
    // every attempt of shard 2 during round 1 fails, whatever the retry
    let hook: FaultHook = Arc::new(|site: FaultSite| {
        if site.round == 1 && site.task == 2 {
            FaultAction::Io("permanent this round".into())
        } else {
            FaultAction::None
        }
    });
    let mut rng = Pcg64::seed_from(909);
    let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
    coord.set_map_fault_hook(Some(hook));

    let r0 = coord.step(&mut rng);
    assert_eq!(r0.quarantined_shards, 0);

    // round 1: retries exhausted → quarantined this round
    let r1 = coord.step(&mut rng);
    assert_eq!(r1.retries, 2, "round 1 should burn the full retry budget");
    assert_eq!(r1.quarantined_shards, 1);
    assert!(coord.quarantined_shards()[2]);
    assert_eq!(coord.quarantine_events(), 1);
    let st = &coord.shard_stats()[2];
    assert_eq!(st.retries, 2);
    assert!(st.quarantined);
    coord.check_invariants().unwrap();

    // rounds 2 and 3: cool-down — the shard enters quarantined (sweeps
    // skipped, no faults fire, its zero-sweep attempt completes clean)
    for round in 2..4u64 {
        let rs = coord.step(&mut rng);
        assert_eq!(rs.quarantined_shards, 1, "round {round} should still be in cool-down");
        assert!(coord.quarantined_shards()[2]);
        assert_eq!(rs.retries, 0);
        coord.check_invariants().unwrap();
    }

    // round 4: reintegrated — full health
    let r4 = coord.step(&mut rng);
    assert_eq!(r4.quarantined_shards, 0, "cool-down should have expired");
    assert!(!coord.quarantined_shards()[2]);
    assert_eq!(coord.quarantine_events(), 1, "no further quarantine entries");
    coord.check_invariants().unwrap();
}

#[test]
fn permanently_failing_shard_still_samples_the_exact_posterior() {
    // gate 3: shard 2's map attempt hits a permanent injected I/O fault
    // EVERY round (max_retries = 0 → immediate degrade; the degraded
    // attempt fails too → snapshot restore). Its rows keep their
    // assignments each round, but its statistics still fold into the α
    // reduce and its clusters still shuffle — so every row still mixes
    // through the healthy shards and the chain samples the exact
    // 203-partition posterior.
    let data = enumeration_fixture();
    const ALPHA: f64 = 1.3;
    const BETA: f64 = 0.6;
    let model = Model::bernoulli(D, BETA);
    let truth = enumerate_posterior(&data, &model, ALPHA);
    assert_eq!(truth.len(), 203); // Bell(6)

    let cfg = CoordinatorConfig {
        workers: 3,
        local_sweeps: 1,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        shuffle: true,
        comm: CommModel::free(),
        parallelism: 1,
        supervise: SuperviseConfig {
            enabled: true,
            max_retries: 0,
            backoff_base: Duration::ZERO,
            backoff_cap: Duration::ZERO,
            round_timeout: None,
            cooldown_rounds: 3,
        },
        ..Default::default()
    };
    let hook: FaultHook = Arc::new(|site: FaultSite| {
        if site.task == 2 {
            FaultAction::Io("permanent".into())
        } else {
            FaultAction::None
        }
    });
    let mut rng = Pcg64::seed_from(77);
    let mut coord = Coordinator::new(&data, cfg, &mut rng);
    coord.set_map_fault_hook(Some(hook));
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let burn = 2_000;
    let rounds = 60_000u64;
    for it in 0..(burn + rounds) {
        coord.step(&mut rng);
        if it >= burn {
            *counts.entry(canonical(&coord.assignments())).or_default() += 1;
        }
    }
    coord.check_invariants().unwrap();
    assert!(coord.quarantine_events() > 0, "the permanent fault never triggered quarantine");
    assert!(coord.quarantined_shards()[2], "shard 2 should still be quarantined at the end");
    let tv = tv_distance(&truth, &counts, rounds);
    assert!(tv < 0.05, "permanent-quarantine TV distance {tv} too large");
}

#[test]
fn supervise_off_keeps_the_legacy_abort_contract() {
    // with supervision off an injected fault aborts the round exactly
    // like an organic shard panic: the step panics and the coordinator
    // is left visibly poisoned (the PR 8 contract failure_injection.rs
    // pins for organic panics)
    let ds = SyntheticConfig {
        n: 64,
        d: 8,
        clusters: 2,
        beta: 0.2,
        seed: 3,
    }
    .generate_with_test_fraction(0.0);
    for action in [
        FaultAction::Panic("legacy".into()),
        FaultAction::Io("legacy".into()),
    ] {
        let cfg = CoordinatorConfig {
            workers: 4,
            comm: CommModel::free(),
            parallelism: 4,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(5);
        let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
        coord.set_map_fault_hook(Some(fault_once(0, 2, action.clone())));
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            coord.step(&mut rng);
        }));
        assert!(res.is_err(), "{action:?} with supervise off should abort");
        assert!(coord.states().is_empty(), "aborted coordinator must be visibly poisoned");
    }
}

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("cc_fault_tolerance").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn torn_generation_is_skipped_and_auto_resume_recovers() {
    // gate 4: run a chain saving a generation ring, tear the newest
    // generation mid-file (a crash mid-save), and verify auto-resume
    // skips it, loads the newest VALID generation, and continues
    let ds = SyntheticConfig {
        n: 60,
        d: 6,
        clusters: 2,
        beta: 0.2,
        seed: 9,
    }
    .generate_with_test_fraction(0.0);
    let cfg = CoordinatorConfig {
        workers: 3,
        update_alpha: true,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let dir = tmpdir("ring");
    let ring = CheckpointDir::new(&dir, 3).unwrap();
    let mut rng = Pcg64::seed_from(31);
    let mut coord = Coordinator::new(&ds.train, cfg.clone(), &mut rng);
    for _ in 0..5 {
        coord.step(&mut rng);
        ring.save(&Checkpoint::capture(&coord), coord.rounds).unwrap();
    }
    // the ring is bounded: 5 generations saved, only `keep` remain
    let gens = ring.generations().unwrap();
    assert_eq!(
        gens.iter().map(|(g, _)| *g).collect::<Vec<_>>(),
        vec![3, 4, 5],
        "ring should keep exactly the newest 3 generations"
    );

    // torn write: truncate the newest generation mid-file
    let (newest, newest_path) = gens.last().unwrap().clone();
    let bytes = std::fs::read(&newest_path).unwrap();
    std::fs::write(&newest_path, &bytes[..bytes.len() / 2]).unwrap();

    let (got, ckpt) = ring
        .load_latest_valid()
        .unwrap()
        .expect("a valid generation must survive the torn write");
    assert_eq!(got, newest - 1, "auto-resume should fall back one generation");

    let mut rng2 = Pcg64::seed_from(32);
    let mut resumed = Coordinator::resume(&ds.train, cfg, &ckpt, &mut rng2).unwrap();
    assert_eq!(resumed.rounds, got);
    resumed.check_invariants().unwrap();
    // the resumed chain keeps running (and keeps saving) cleanly
    for _ in 0..3 {
        resumed.step(&mut rng2);
        ring.save(&Checkpoint::capture(&resumed), resumed.rounds).unwrap();
        resumed.check_invariants().unwrap();
    }
    assert_eq!(resumed.rounds, got + 3);
    let _ = std::fs::remove_dir_all(&dir);
}
