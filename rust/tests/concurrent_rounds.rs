//! The deterministic concurrency test layer for the barrier-free
//! coordinator (`--overlap on`): a seeded [`DelayHook`] pins pool
//! completion order, and the suite asserts the chain state that comes
//! out of the concurrent host pipeline is a pure function of the seed —
//! never of thread scheduling, completion order, or injected delays.
//!
//! Three gates:
//!
//! 1. **completion-order permutations** — a K=4 run with α, β, and μ
//!    all updating must produce the identical partition / α bits / μ
//!    bits / shuffle-decision sequence under every exercised completion
//!    order (all 24 permutations with `CC_PERM_SWEEP=all`, a structured
//!    subset by default), and identical to the inline (no-pool)
//!    schedule.
//! 2. **K=1 bit-identity** — with overlap on, real injected delays, and
//!    α+β updates, the coordinator chain stays bit-identical to
//!    [`SerialGibbs`] sweep-by-sweep (the strongest exactness anchor).
//! 3. **real threads** — 200 overlapped rounds on the unevenly sharded
//!    enumeration fixture, run(parallelism=1) == run(parallelism=3)
//!    with invariants and measured-schedule columns checked every round.

use clustercluster::coordinator::{Coordinator, CoordinatorConfig, MuMode, ShuffleMove};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::mapreduce::{CommModel, DelayHook};
use clustercluster::rng::Pcg64;
use clustercluster::serial::{SerialConfig, SerialGibbs};
use clustercluster::testing::{canonical_partition as canonical, enumeration_fixture};
use std::sync::Arc;
use std::time::Duration;

/// A [`DelayHook`] that sleeps `delays_ms[i]` before base task `i`
/// (indexes past the end get no delay).
fn hook_from_delays(delays_ms: Vec<u64>) -> DelayHook {
    Arc::new(move |i| Duration::from_millis(delays_ms.get(i).copied().unwrap_or(0)))
}

/// All n! orderings of `0..n` (Heap's algorithm).
fn all_permutations(n: usize) -> Vec<Vec<usize>> {
    fn heap(k: usize, arr: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if k <= 1 {
            out.push(arr.clone());
            return;
        }
        for i in 0..k {
            heap(k - 1, arr, out);
            if k % 2 == 0 {
                arr.swap(i, k - 1);
            } else {
                arr.swap(0, k - 1);
            }
        }
    }
    let mut arr: Vec<usize> = (0..n).collect();
    let mut out = Vec::new();
    heap(n, &mut arr, &mut out);
    out
}

/// The completion orders the default CI run exercises: identity,
/// reverse, every rotation, and one adjacent swap — the structured
/// representatives of the interesting interleavings. `CC_PERM_SWEEP=all`
/// expands to the full n! sweep (the nightly/exhaustive gate).
fn exercised_permutations(n: usize) -> Vec<Vec<usize>> {
    if std::env::var("CC_PERM_SWEEP").map(|v| v == "all").unwrap_or(false) {
        return all_permutations(n);
    }
    let identity: Vec<usize> = (0..n).collect();
    let mut subset = vec![identity.clone(), (0..n).rev().collect()];
    for r in 1..n {
        subset.push((0..n).map(|i| (i + r) % n).collect());
    }
    let mut swapped = identity;
    swapped.swap(0, 1);
    subset.push(swapped);
    subset.sort();
    subset.dedup();
    subset
}

/// Everything schedule-independence must hold over: the partition, the
/// α and μ bit patterns, and the full shuffle-decision sequence of the
/// final round (the drain-order observable).
#[derive(Debug, PartialEq)]
struct Fingerprint {
    partition: Vec<u8>,
    alpha_bits: u64,
    mu_bits: Vec<u64>,
    moves: Vec<ShuffleMove>,
}

/// One fixed-seed K=4 overlapped run with every global update live
/// (α, griddy-Gibbs β, size-proportional μ) under the given host
/// schedule: `parallelism` threads and an optional completion-order
/// delay hook.
fn run_k4(parallelism: usize, hook: Option<DelayHook>) -> Fingerprint {
    let ds = SyntheticConfig {
        n: 96,
        d: 8,
        clusters: 3,
        beta: 0.2,
        seed: 7,
    }
    .generate_with_test_fraction(0.0);
    let cfg = CoordinatorConfig {
        workers: 4,
        update_alpha: true,
        update_beta: true,
        mu_mode: MuMode::SizeProportional,
        comm: CommModel::free(),
        parallelism,
        overlap: true,
        max_bonus_sweeps: 2,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(4242);
    let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
    coord.set_map_delay_hook(hook);
    for _ in 0..6 {
        coord.step(&mut rng);
        coord.check_invariants().unwrap();
    }
    Fingerprint {
        partition: canonical(&coord.assignments()),
        alpha_bits: coord.alpha().to_bits(),
        mu_bits: coord.mu().iter().map(|m| m.to_bits()).collect(),
        moves: coord.last_shuffle_moves().to_vec(),
    }
}

#[test]
fn chain_state_is_independent_of_completion_order() {
    // inline (no pool) is the canonical schedule; the pool with no
    // injected delays must reproduce it exactly
    let reference = run_k4(1, None);
    assert!(
        !reference.moves.is_empty(),
        "fixture produced no shuffle decisions — the drain-order observable is empty"
    );
    assert_eq!(
        reference,
        run_k4(4, None),
        "pooled schedule with no injected delays diverged from inline"
    );
    // ...and so must every forced completion order: shard perm[j] is
    // delayed j*12ms, so base completions land in exactly perm order
    for perm in exercised_permutations(4) {
        let mut delays = vec![0u64; 4];
        for (pos, &shard) in perm.iter().enumerate() {
            delays[shard] = pos as u64 * 12;
        }
        assert_eq!(
            reference,
            run_k4(4, Some(hook_from_delays(delays))),
            "completion order {perm:?} perturbed the chain"
        );
    }
}

#[test]
fn k1_overlap_stays_bit_identical_to_serial_under_injected_delays() {
    // the strongest anchor: at K=1 the concurrent overlapped schedule —
    // even with a real injected delay on the (single) map task and both
    // α and β updating — must stay bit-identical to the serial chain at
    // every sweep. Nothing is drained or snapshotted out of order, and
    // the master stream is consumed α → β exactly as serially.
    let ds = SyntheticConfig {
        n: 80,
        d: 8,
        clusters: 3,
        beta: 0.15,
        seed: 11,
    }
    .generate_with_test_fraction(0.0);
    let seed = 501;

    let scfg = SerialConfig {
        init_alpha: 1.5,
        init_beta: 0.4,
        update_alpha: true,
        update_beta: true,
        ..Default::default()
    };
    let mut srng = Pcg64::seed_from(seed);
    let mut serial = SerialGibbs::init_from_prior(&ds.train, scfg, &mut srng);

    let ccfg = CoordinatorConfig {
        workers: 1,
        init_alpha: 1.5,
        init_beta: 0.4,
        update_alpha: true,
        update_beta: true,
        comm: CommModel::free(),
        parallelism: 1,
        overlap: true,
        max_bonus_sweeps: 3,
        ..Default::default()
    };
    let mut crng = Pcg64::seed_from(seed);
    let mut coord = Coordinator::new(&ds.train, ccfg, &mut crng);
    coord.set_map_delay_hook(Some(hook_from_delays(vec![1])));

    for it in 0..40 {
        serial.sweep(&mut srng);
        coord.step(&mut crng);
        assert_eq!(
            canonical(serial.assignments()),
            canonical(&coord.assignments()),
            "partitions diverged at sweep {it}"
        );
        assert_eq!(
            serial.alpha().to_bits(),
            coord.alpha().to_bits(),
            "α diverged at sweep {it}: serial {} vs coordinator {}",
            serial.alpha(),
            coord.alpha()
        );
    }
    serial.check_invariants().unwrap();
    coord.check_invariants().unwrap();
}

#[test]
fn overlapped_integrity_holds_under_real_threads() {
    // 200 overlapped rounds on the unevenly sharded 6-row fixture, with
    // real pool threads racing real bonus grants: state integrity and
    // the measured-schedule columns hold every round, the work-stealing
    // path provably fires, and the chain lands in exactly the state the
    // inline schedule produces
    let data = enumeration_fixture();
    let run = |parallelism: usize| -> (Vec<u8>, u64, u64) {
        let cfg = CoordinatorConfig {
            workers: 3,
            update_alpha: true,
            update_beta: false,
            comm: CommModel::free(),
            parallelism,
            overlap: true,
            max_bonus_sweeps: 2,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(91);
        let mut coord = Coordinator::new(&data, cfg, &mut rng);
        for _ in 0..200 {
            let rs = coord.step(&mut rng);
            assert!(rs.measured_overlapped_s > 0.0);
            assert!(rs.measured_serialized_s > 0.0);
            coord.check_invariants().unwrap();
        }
        let granted: u64 = coord.states().iter().map(|s| s.bonus_sweeps()).sum();
        assert!(
            granted > 0,
            "200 overlapped rounds granted no bonus sweeps at parallelism {parallelism}"
        );
        (
            canonical(&coord.assignments()),
            coord.alpha().to_bits(),
            granted,
        )
    };
    assert_eq!(
        run(1),
        run(3),
        "real-thread schedule diverged from the inline schedule"
    );
}
