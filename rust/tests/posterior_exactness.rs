//! The strongest correctness evidence in the repo: on a tiny dataset we
//! ENUMERATE every partition, compute the exact DPM posterior, and check
//! that (a) the serial Neal-Alg.-3 chain and (b) the parallel
//! supercluster coordinator (K = 2 and 3, with shuffling) both converge
//! to it in total-variation distance.
//!
//! This validates the paper's central claim end-to-end: the auxiliary
//! supercluster representation leaves the TRUE DPM posterior invariant —
//! including the `αμ_k` scaling of local CRPs, the cluster shuffle, and
//! per-shard global moves (the Jain–Neal split–merge composites, alone
//! and mixed with plain Gibbs across shards).
//!
//! The serial chains run under BOTH sweep-scoring dispatches: the scalar
//! reference path and the batched `Scorer` path (which is also what the
//! coordinator runs by default), so the gate certifies the batched
//! restructuring directly, not only via bit-equivalence.

use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::DataRef;
use clustercluster::mapreduce::CommModel;
use clustercluster::model::{Model, ModelSpec};
use clustercluster::rng::Pcg64;
use clustercluster::runtime::ScorerKind;
use clustercluster::sampler::{KernelKind, ScoreMode};
use clustercluster::serial::{SerialConfig, SerialGibbs};
use clustercluster::testing::{
    canonical_partition as canonical, enumerate_posterior, enumeration_fixture as tiny_data,
    enumeration_fixture_cat, enumeration_fixture_real, partition_tv_distance as tv_distance,
    ENUM_D as D,
};
use std::collections::HashMap;

const ALPHA: f64 = 1.3;
const BETA: f64 = 0.6;

/// The exact posterior over the 203 partitions of the shared 6-row
/// enumeration fixture (machinery lives in `clustercluster::testing`,
/// shared with `rust/tests/mu_modes.rs`).
fn exact_posterior(
    data: &clustercluster::data::BinMat,
    model: &Model,
) -> HashMap<Vec<u8>, f64> {
    let post = enumerate_posterior(data, model, ALPHA);
    assert_eq!(post.len(), 203); // Bell(6)
    post
}

fn serial_tv(
    kernel: clustercluster::sampler::KernelKind,
    scoring: clustercluster::sampler::ScoreMode,
    seed: u64,
) -> f64 {
    let data = tiny_data();
    let model = Model::bernoulli(D, BETA);
    let truth = exact_posterior(&data, &model);

    let cfg = SerialConfig {
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        kernel,
        scoring,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(seed);
    let mut g = SerialGibbs::init_from_prior(&data, cfg, &mut rng);
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let burn = 2_000;
    let samples = 60_000u64;
    for it in 0..(burn + samples) {
        g.sweep(&mut rng);
        if it >= burn {
            *counts.entry(canonical(g.assignments())).or_default() += 1;
        }
    }
    tv_distance(&truth, &counts, samples)
}

#[test]
fn serial_gibbs_matches_enumerated_posterior() {
    // the pre-batching scalar dispatch, pinned explicitly as the reference
    let tv = serial_tv(
        clustercluster::sampler::KernelKind::CollapsedGibbs,
        clustercluster::sampler::ScoreMode::Scalar,
        11,
    );
    assert!(tv < 0.05, "serial TV distance {tv} too large");
}

#[test]
fn serial_walker_matches_enumerated_posterior() {
    // the same WalkerSlice kernel object that the coordinator dispatches
    // must also be exact when driven by the serial entry point
    let tv = serial_tv(
        clustercluster::sampler::KernelKind::WalkerSlice,
        clustercluster::sampler::ScoreMode::Scalar,
        12,
    );
    assert!(tv < 0.05, "serial Walker TV distance {tv} too large");
}

#[test]
fn serial_gibbs_batched_dispatch_matches_enumerated_posterior() {
    // the 203-partition gate also certifies the batched Scorer dispatch
    // (independent seed from the scalar run, so this is not a replay)
    let tv = serial_tv(
        clustercluster::sampler::KernelKind::CollapsedGibbs,
        clustercluster::sampler::ScoreMode::Batched(
            clustercluster::runtime::ScorerKind::Fallback,
        ),
        13,
    );
    assert!(tv < 0.05, "serial batched TV distance {tv} too large");
}

#[test]
fn serial_walker_batched_dispatch_matches_enumerated_posterior() {
    let tv = serial_tv(
        clustercluster::sampler::KernelKind::WalkerSlice,
        clustercluster::sampler::ScoreMode::Batched(
            clustercluster::runtime::ScorerKind::Fallback,
        ),
        14,
    );
    assert!(tv < 0.05, "serial Walker batched TV distance {tv} too large");
}

#[test]
fn serial_split_merge_matches_enumerated_posterior() {
    // the Jain–Neal split–merge composite over the scalar reference
    // dispatch: the MH move layer + collapsed-Gibbs sweep must leave the
    // exact posterior invariant
    let tv = serial_tv(
        clustercluster::sampler::KernelKind::SplitMergeGibbs,
        clustercluster::sampler::ScoreMode::Scalar,
        15,
    );
    assert!(tv < 0.05, "serial split-merge TV distance {tv} too large");
}

#[test]
fn serial_split_merge_walker_batched_matches_enumerated_posterior() {
    // the Walker-based composite through the batched Scorer dispatch —
    // the restricted scans share the packed-table path, so this gates
    // the move layer's table maintenance statistically too
    let tv = serial_tv(
        clustercluster::sampler::KernelKind::SplitMergeWalker,
        clustercluster::sampler::ScoreMode::Batched(
            clustercluster::runtime::ScorerKind::Fallback,
        ),
        16,
    );
    assert!(
        tv < 0.05,
        "serial split-merge:walker batched TV distance {tv} too large"
    );
}

fn coordinator_tv_assignment(
    workers: usize,
    seed: u64,
    rounds: u64,
    kernel_assignment: clustercluster::sampler::KernelAssignment,
) -> f64 {
    coordinator_tv_assignment_sched(workers, seed, rounds, kernel_assignment, false, 1)
}

fn coordinator_tv_assignment_sched(
    workers: usize,
    seed: u64,
    rounds: u64,
    kernel_assignment: clustercluster::sampler::KernelAssignment,
    overlap: bool,
    parallelism: usize,
) -> f64 {
    let data = tiny_data();
    let model = Model::bernoulli(D, BETA);
    let truth = exact_posterior(&data, &model);

    let cfg = CoordinatorConfig {
        workers,
        local_sweeps: 1,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        shuffle: true,
        kernel_assignment,
        comm: CommModel::free(),
        parallelism,
        overlap,
        // the 6-row fixture shards unevenly most rounds, so the
        // overlapped schedule's work-stealing grants fire constantly —
        // the gate certifies bonus sweeps statistically, not just the
        // stage reordering
        max_bonus_sweeps: 2,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(seed);
    let mut coord = Coordinator::new(&data, cfg, &mut rng);
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let burn = 2_000;
    for it in 0..(burn + rounds) {
        coord.step(&mut rng);
        if it >= burn {
            *counts.entry(canonical(&coord.assignments())).or_default() += 1;
        }
    }
    coord.check_invariants().unwrap();
    tv_distance(&truth, &counts, rounds)
}

fn coordinator_tv_kernel(
    workers: usize,
    seed: u64,
    rounds: u64,
    kernel: clustercluster::coordinator::LocalKernel,
) -> f64 {
    coordinator_tv_assignment(
        workers,
        seed,
        rounds,
        clustercluster::sampler::KernelAssignment::AllSame(kernel),
    )
}

#[test]
fn walker_slice_kernel_matches_enumerated_posterior() {
    // the Walker (2007) per-supercluster kernel must hit the same exact
    // posterior as collapsed Gibbs (paper §4: standard DPM techniques
    // apply per supercluster without modification)
    let tv = coordinator_tv_kernel(
        2,
        31,
        60_000,
        clustercluster::coordinator::LocalKernel::WalkerSlice,
    );
    assert!(tv < 0.05, "Walker K=2 TV distance {tv} too large");
}

#[test]
fn split_merge_kernel_k3_matches_enumerated_posterior() {
    // split–merge moves inside every supercluster, composed with the
    // cluster shuffle: the paper's argument covers global moves too —
    // each shard's conditional is a DP(αμ_k, H) mixture, so the Jain–
    // Neal operator applies per shard without modification
    let tv = coordinator_tv_kernel(
        3,
        32,
        60_000,
        clustercluster::coordinator::LocalKernel::SplitMergeGibbs,
    );
    assert!(tv < 0.05, "split-merge K=3 TV distance {tv} too large");
}

#[test]
fn mixed_gibbs_and_split_merge_walker_k3_matches_enumerated_posterior() {
    // mixed per-shard assignment `--local-kernel gibbs,split_merge:walker`
    // at K=3: shards 0/2 run plain Gibbs, shard 1 the Walker-based
    // split–merge composite — one exact chain across heterogeneous
    // operators including the global-move layer
    let tv = coordinator_tv_assignment(
        3,
        34,
        60_000,
        clustercluster::sampler::KernelAssignment::parse("gibbs,split_merge:walker").unwrap(),
    );
    assert!(
        tv < 0.05,
        "mixed gibbs/split-merge:walker K=3 TV distance {tv} too large"
    );
}

#[test]
fn coordinator_k3_overlap_matches_enumerated_posterior() {
    // the barrier-free schedule (`--overlap on`): staged shuffle
    // decided against the pre-update α/μ, hyper/μ updates on the
    // post-shuffle reduced stats, and work-stealing bonus sweeps —
    // still a composition of invariant kernels, so the 203-partition
    // gate must hold exactly as for the bulk-synchronous reference
    let tv = coordinator_tv_assignment_sched(
        3,
        42,
        60_000,
        clustercluster::sampler::KernelAssignment::default(),
        true,
        1,
    );
    assert!(tv < 0.05, "K=3 overlapped TV distance {tv} too large");
}

#[test]
fn coordinator_k3_overlap_concurrent_matches_enumerated_posterior() {
    // the same barrier-free gate, but on REAL pool threads (parallelism
    // 3): completions stream back in whatever order the host produces,
    // staging interleaves with live sweeps, and bonus grants launch
    // mid-window — the 203-partition posterior must be untouched
    let tv = coordinator_tv_assignment_sched(
        3,
        46,
        60_000,
        clustercluster::sampler::KernelAssignment::default(),
        true,
        3,
    );
    assert!(tv < 0.05, "K=3 concurrent overlapped TV distance {tv} too large");
}

#[test]
fn mixed_kernels_k3_overlap_matches_enumerated_posterior() {
    // overlap × heterogeneous kernels: bonus sweeps replay each shard's
    // OWN kernel (Gibbs on shards 0/2, the Walker split–merge composite
    // on shard 1), so the grant must stay exact across mixed operators
    let tv = coordinator_tv_assignment_sched(
        3,
        44,
        60_000,
        clustercluster::sampler::KernelAssignment::parse("gibbs,split_merge:walker").unwrap(),
        true,
        1,
    );
    assert!(
        tv < 0.05,
        "mixed-kernel K=3 overlapped TV distance {tv} too large"
    );
}

#[test]
fn mixed_kernels_k3_overlap_concurrent_matches_enumerated_posterior() {
    // concurrent scheduler × heterogeneous kernels: a mid-window bonus
    // grant resubmits the shard with its OWN kernel as a fresh pool job
    // racing the other shards' base sweeps — still exact
    let tv = coordinator_tv_assignment_sched(
        3,
        47,
        60_000,
        clustercluster::sampler::KernelAssignment::parse("gibbs,split_merge:walker").unwrap(),
        true,
        3,
    );
    assert!(
        tv < 0.05,
        "mixed-kernel K=3 concurrent overlapped TV distance {tv} too large"
    );
}

fn coordinator_tv(workers: usize, seed: u64, rounds: u64) -> f64 {
    let data = tiny_data();
    let model = Model::bernoulli(D, BETA);
    let truth = exact_posterior(&data, &model);

    let cfg = CoordinatorConfig {
        workers,
        local_sweeps: 1,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        shuffle: true,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(seed);
    let mut coord = Coordinator::new(&data, cfg, &mut rng);
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let burn = 2_000;
    for it in 0..(burn + rounds) {
        coord.step(&mut rng);
        if it >= burn {
            *counts.entry(canonical(&coord.assignments())).or_default() += 1;
        }
    }
    coord.check_invariants().unwrap();
    tv_distance(&truth, &counts, rounds)
}

#[test]
fn coordinator_k2_matches_enumerated_posterior() {
    let tv = coordinator_tv(2, 21, 60_000);
    assert!(tv < 0.05, "K=2 coordinator TV distance {tv} too large");
}

#[test]
fn coordinator_k3_matches_enumerated_posterior() {
    let tv = coordinator_tv(3, 22, 60_000);
    assert!(tv < 0.05, "K=3 coordinator TV distance {tv} too large");
}

#[test]
fn no_shuffle_ablation_is_biased() {
    // without the shuffle step data can never merge across superclusters:
    // the chain is NOT a DPM sampler — the design ablation of DESIGN.md §10.
    let data = tiny_data();
    let model = Model::bernoulli(D, BETA);
    let truth = exact_posterior(&data, &model);
    let cfg = CoordinatorConfig {
        workers: 3,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        shuffle: false,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(33);
    let mut coord = Coordinator::new(&data, cfg, &mut rng);
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let rounds = 40_000u64;
    for it in 0..(1000 + rounds) {
        coord.step(&mut rng);
        if it >= 1000 {
            *counts.entry(canonical(&coord.assignments())).or_default() += 1;
        }
    }
    let tv = tv_distance(&truth, &counts, rounds);
    assert!(
        tv > 0.10,
        "no-shuffle chain unexpectedly matched the posterior (TV {tv})"
    );
}

// ---------------------------------------------------------------------
// Likelihood-generic gates: the SAME 203-partition machinery, run under
// the collapsed diagonal-Gaussian (NIG) and Dirichlet–multinomial
// component models — serial and K=3 coordinator, scalar and batched
// scoring dispatches. This is the statistical certificate that the
// ComponentModel extraction left every sampler layer exact for the new
// likelihoods, not just for the Bernoulli path the older gates pin.
// ---------------------------------------------------------------------

fn serial_tv_model(
    spec: ModelSpec,
    data: DataRef<'_>,
    kernel: KernelKind,
    scoring: ScoreMode,
    seed: u64,
) -> f64 {
    let model = spec.build(data, BETA).unwrap();
    let truth = enumerate_posterior(data, &model, ALPHA);
    assert_eq!(truth.len(), 203); // Bell(6)
    let cfg = SerialConfig {
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        kernel,
        scoring,
        model: spec,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(seed);
    let mut g = SerialGibbs::init_from_prior(data, cfg, &mut rng);
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let burn = 2_000;
    let samples = 60_000u64;
    for it in 0..(burn + samples) {
        g.sweep(&mut rng);
        if it >= burn {
            *counts.entry(canonical(g.assignments())).or_default() += 1;
        }
    }
    tv_distance(&truth, &counts, samples)
}

fn coordinator_tv_model(
    spec: ModelSpec,
    data: DataRef<'_>,
    workers: usize,
    scoring: ScoreMode,
    seed: u64,
) -> f64 {
    coordinator_tv_model_sched(spec, data, workers, scoring, seed, false, 1)
}

fn coordinator_tv_model_sched(
    spec: ModelSpec,
    data: DataRef<'_>,
    workers: usize,
    scoring: ScoreMode,
    seed: u64,
    overlap: bool,
    parallelism: usize,
) -> f64 {
    let model = spec.build(data, BETA).unwrap();
    let truth = enumerate_posterior(data, &model, ALPHA);
    assert_eq!(truth.len(), 203);
    let cfg = CoordinatorConfig {
        workers,
        local_sweeps: 1,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        shuffle: true,
        scoring,
        comm: CommModel::free(),
        parallelism,
        overlap,
        max_bonus_sweeps: 2,
        model: spec,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(seed);
    let mut coord = Coordinator::new(data, cfg, &mut rng);
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let burn = 2_000;
    let rounds = 60_000u64;
    for it in 0..(burn + rounds) {
        coord.step(&mut rng);
        if it >= burn {
            *counts.entry(canonical(&coord.assignments())).or_default() += 1;
        }
    }
    coord.check_invariants().unwrap();
    tv_distance(&truth, &counts, rounds)
}

#[test]
fn gaussian_serial_matches_enumerated_posterior() {
    let data = enumeration_fixture_real();
    let tv = serial_tv_model(
        ModelSpec::DEFAULT_GAUSSIAN,
        (&data).into(),
        KernelKind::CollapsedGibbs,
        ScoreMode::Scalar,
        61,
    );
    assert!(tv < 0.05, "gaussian serial TV distance {tv} too large");
}

#[test]
fn gaussian_serial_batched_matches_enumerated_posterior() {
    // the batched dispatch drives the two-plane real scoring path
    // (Scorer::score_real_against_clusters) statistically
    let data = enumeration_fixture_real();
    let tv = serial_tv_model(
        ModelSpec::DEFAULT_GAUSSIAN,
        (&data).into(),
        KernelKind::CollapsedGibbs,
        ScoreMode::Batched(ScorerKind::Fallback),
        62,
    );
    assert!(tv < 0.05, "gaussian batched TV distance {tv} too large");
}

#[test]
fn categorical_serial_matches_enumerated_posterior() {
    let data = enumeration_fixture_cat();
    let tv = serial_tv_model(
        ModelSpec::DEFAULT_CATEGORICAL,
        (&data).into(),
        KernelKind::CollapsedGibbs,
        ScoreMode::Scalar,
        63,
    );
    assert!(tv < 0.05, "categorical serial TV distance {tv} too large");
}

#[test]
fn categorical_serial_batched_matches_enumerated_posterior() {
    // the categorical model rides the one-hot bit-sparse packed path —
    // the same score_ones_against_clusters kernel as Bernoulli
    let data = enumeration_fixture_cat();
    let tv = serial_tv_model(
        ModelSpec::DEFAULT_CATEGORICAL,
        (&data).into(),
        KernelKind::CollapsedGibbs,
        ScoreMode::Batched(ScorerKind::Fallback),
        64,
    );
    assert!(tv < 0.05, "categorical batched TV distance {tv} too large");
}

#[test]
fn gaussian_coordinator_k3_matches_enumerated_posterior() {
    let data = enumeration_fixture_real();
    let tv = coordinator_tv_model(
        ModelSpec::DEFAULT_GAUSSIAN,
        (&data).into(),
        3,
        ScoreMode::Scalar,
        65,
    );
    assert!(tv < 0.05, "gaussian K=3 TV distance {tv} too large");
}

#[test]
fn gaussian_coordinator_k3_batched_matches_enumerated_posterior() {
    let data = enumeration_fixture_real();
    let tv = coordinator_tv_model(
        ModelSpec::DEFAULT_GAUSSIAN,
        (&data).into(),
        3,
        ScoreMode::Batched(ScorerKind::Fallback),
        66,
    );
    assert!(tv < 0.05, "gaussian K=3 batched TV distance {tv} too large");
}

#[test]
fn categorical_coordinator_k3_matches_enumerated_posterior() {
    let data = enumeration_fixture_cat();
    let tv = coordinator_tv_model(
        ModelSpec::DEFAULT_CATEGORICAL,
        (&data).into(),
        3,
        ScoreMode::Scalar,
        67,
    );
    assert!(tv < 0.05, "categorical K=3 TV distance {tv} too large");
}

#[test]
fn categorical_coordinator_k3_batched_matches_enumerated_posterior() {
    let data = enumeration_fixture_cat();
    let tv = coordinator_tv_model(
        ModelSpec::DEFAULT_CATEGORICAL,
        (&data).into(),
        3,
        ScoreMode::Batched(ScorerKind::Fallback),
        68,
    );
    assert!(
        tv < 0.05,
        "categorical K=3 batched TV distance {tv} too large"
    );
}

#[test]
fn gaussian_coordinator_k3_overlap_concurrent_matches_enumerated_posterior() {
    // the concurrent barrier-free scheduler under the collapsed
    // diagonal-Gaussian likelihood: β staging is a structural no-op
    // here (non-Bernoulli), so this gates the J-snapshot α path and the
    // canonical-order drain on a likelihood with real-valued stats
    let data = enumeration_fixture_real();
    let tv = coordinator_tv_model_sched(
        ModelSpec::DEFAULT_GAUSSIAN,
        (&data).into(),
        3,
        ScoreMode::Scalar,
        69,
        true,
        3,
    );
    assert!(
        tv < 0.05,
        "gaussian K=3 concurrent overlapped TV distance {tv} too large"
    );
}

#[test]
fn categorical_coordinator_k3_overlap_concurrent_matches_enumerated_posterior() {
    // same gate under the Dirichlet–multinomial likelihood (one-hot
    // packed path), closing the likelihood × scheduler matrix
    let data = enumeration_fixture_cat();
    let tv = coordinator_tv_model_sched(
        ModelSpec::DEFAULT_CATEGORICAL,
        (&data).into(),
        3,
        ScoreMode::Scalar,
        70,
        true,
        3,
    );
    assert!(
        tv < 0.05,
        "categorical K=3 concurrent overlapped TV distance {tv} too large"
    );
}
