//! The strongest correctness evidence in the repo: on a tiny dataset we
//! ENUMERATE every partition, compute the exact DPM posterior, and check
//! that (a) the serial Neal-Alg.-3 chain and (b) the parallel
//! supercluster coordinator (K = 2 and 3, with shuffling) both converge
//! to it in total-variation distance.
//!
//! This validates the paper's central claim end-to-end: the auxiliary
//! supercluster representation leaves the TRUE DPM posterior invariant —
//! including the `αμ_k` scaling of local CRPs and the cluster shuffle.
//!
//! The serial chains run under BOTH sweep-scoring dispatches: the scalar
//! reference path and the batched `Scorer` path (which is also what the
//! coordinator runs by default), so the gate certifies the batched
//! restructuring directly, not only via bit-equivalence.

use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::mapreduce::CommModel;
use clustercluster::model::BetaBernoulli;
use clustercluster::rng::Pcg64;
use clustercluster::serial::{SerialConfig, SerialGibbs};
use clustercluster::testing::{
    canonical_partition as canonical, enumerate_posterior, enumeration_fixture as tiny_data,
    partition_tv_distance as tv_distance, ENUM_D as D,
};
use std::collections::HashMap;

const ALPHA: f64 = 1.3;
const BETA: f64 = 0.6;

/// The exact posterior over the 203 partitions of the shared 6-row
/// enumeration fixture (machinery lives in `clustercluster::testing`,
/// shared with `rust/tests/mu_modes.rs`).
fn exact_posterior(
    data: &clustercluster::data::BinMat,
    model: &BetaBernoulli,
) -> HashMap<Vec<u8>, f64> {
    let post = enumerate_posterior(data, model, ALPHA);
    assert_eq!(post.len(), 203); // Bell(6)
    post
}

fn serial_tv(
    kernel: clustercluster::sampler::KernelKind,
    scoring: clustercluster::sampler::ScoreMode,
    seed: u64,
) -> f64 {
    let data = tiny_data();
    let model = BetaBernoulli::symmetric(D, BETA);
    let truth = exact_posterior(&data, &model);

    let cfg = SerialConfig {
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        kernel,
        scoring,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(seed);
    let mut g = SerialGibbs::init_from_prior(&data, cfg, &mut rng);
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let burn = 2_000;
    let samples = 60_000u64;
    for it in 0..(burn + samples) {
        g.sweep(&mut rng);
        if it >= burn {
            *counts.entry(canonical(g.assignments())).or_default() += 1;
        }
    }
    tv_distance(&truth, &counts, samples)
}

#[test]
fn serial_gibbs_matches_enumerated_posterior() {
    // the pre-batching scalar dispatch, pinned explicitly as the reference
    let tv = serial_tv(
        clustercluster::sampler::KernelKind::CollapsedGibbs,
        clustercluster::sampler::ScoreMode::Scalar,
        11,
    );
    assert!(tv < 0.05, "serial TV distance {tv} too large");
}

#[test]
fn serial_walker_matches_enumerated_posterior() {
    // the same WalkerSlice kernel object that the coordinator dispatches
    // must also be exact when driven by the serial entry point
    let tv = serial_tv(
        clustercluster::sampler::KernelKind::WalkerSlice,
        clustercluster::sampler::ScoreMode::Scalar,
        12,
    );
    assert!(tv < 0.05, "serial Walker TV distance {tv} too large");
}

#[test]
fn serial_gibbs_batched_dispatch_matches_enumerated_posterior() {
    // the 203-partition gate also certifies the batched Scorer dispatch
    // (independent seed from the scalar run, so this is not a replay)
    let tv = serial_tv(
        clustercluster::sampler::KernelKind::CollapsedGibbs,
        clustercluster::sampler::ScoreMode::Batched(
            clustercluster::runtime::ScorerKind::Fallback,
        ),
        13,
    );
    assert!(tv < 0.05, "serial batched TV distance {tv} too large");
}

#[test]
fn serial_walker_batched_dispatch_matches_enumerated_posterior() {
    let tv = serial_tv(
        clustercluster::sampler::KernelKind::WalkerSlice,
        clustercluster::sampler::ScoreMode::Batched(
            clustercluster::runtime::ScorerKind::Fallback,
        ),
        14,
    );
    assert!(tv < 0.05, "serial Walker batched TV distance {tv} too large");
}

fn coordinator_tv_kernel(
    workers: usize,
    seed: u64,
    rounds: u64,
    kernel: clustercluster::coordinator::LocalKernel,
) -> f64 {
    let data = tiny_data();
    let model = BetaBernoulli::symmetric(D, BETA);
    let truth = exact_posterior(&data, &model);

    let cfg = CoordinatorConfig {
        workers,
        local_sweeps: 1,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        shuffle: true,
        kernel_assignment: clustercluster::sampler::KernelAssignment::AllSame(kernel),
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(seed);
    let mut coord = Coordinator::new(&data, cfg, &mut rng);
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let burn = 2_000;
    for it in 0..(burn + rounds) {
        coord.step(&mut rng);
        if it >= burn {
            *counts.entry(canonical(&coord.assignments())).or_default() += 1;
        }
    }
    coord.check_invariants().unwrap();
    tv_distance(&truth, &counts, rounds)
}

#[test]
fn walker_slice_kernel_matches_enumerated_posterior() {
    // the Walker (2007) per-supercluster kernel must hit the same exact
    // posterior as collapsed Gibbs (paper §4: standard DPM techniques
    // apply per supercluster without modification)
    let tv = coordinator_tv_kernel(
        2,
        31,
        60_000,
        clustercluster::coordinator::LocalKernel::WalkerSlice,
    );
    assert!(tv < 0.05, "Walker K=2 TV distance {tv} too large");
}

fn coordinator_tv(workers: usize, seed: u64, rounds: u64) -> f64 {
    let data = tiny_data();
    let model = BetaBernoulli::symmetric(D, BETA);
    let truth = exact_posterior(&data, &model);

    let cfg = CoordinatorConfig {
        workers,
        local_sweeps: 1,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        shuffle: true,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(seed);
    let mut coord = Coordinator::new(&data, cfg, &mut rng);
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let burn = 2_000;
    for it in 0..(burn + rounds) {
        coord.step(&mut rng);
        if it >= burn {
            *counts.entry(canonical(&coord.assignments())).or_default() += 1;
        }
    }
    coord.check_invariants().unwrap();
    tv_distance(&truth, &counts, rounds)
}

#[test]
fn coordinator_k2_matches_enumerated_posterior() {
    let tv = coordinator_tv(2, 21, 60_000);
    assert!(tv < 0.05, "K=2 coordinator TV distance {tv} too large");
}

#[test]
fn coordinator_k3_matches_enumerated_posterior() {
    let tv = coordinator_tv(3, 22, 60_000);
    assert!(tv < 0.05, "K=3 coordinator TV distance {tv} too large");
}

#[test]
fn no_shuffle_ablation_is_biased() {
    // without the shuffle step data can never merge across superclusters:
    // the chain is NOT a DPM sampler — the design ablation of DESIGN.md §7.
    let data = tiny_data();
    let model = BetaBernoulli::symmetric(D, BETA);
    let truth = exact_posterior(&data, &model);
    let cfg = CoordinatorConfig {
        workers: 3,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        shuffle: false,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(33);
    let mut coord = Coordinator::new(&data, cfg, &mut rng);
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let rounds = 40_000u64;
    for it in 0..(1000 + rounds) {
        coord.step(&mut rng);
        if it >= 1000 {
            *counts.entry(canonical(&coord.assignments())).or_default() += 1;
        }
    }
    let tv = tv_distance(&truth, &counts, rounds);
    assert!(
        tv > 0.10,
        "no-shuffle chain unexpectedly matched the posterior (TV {tv})"
    );
}
