//! Property-test suites (via the in-repo `testing` harness — proptest is
//! not in the offline crate universe): randomized invariants over the
//! representation theorems, sufficient statistics, packing, and the
//! coordinator's state machinery.

use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::data::BinMat;
use clustercluster::mapreduce::CommModel;
use clustercluster::model::{ClusterStats, Model};
use clustercluster::rng::{dirichlet, Pcg64};
use clustercluster::runtime::{FallbackScorer, Scorer};
use clustercluster::sampler::{ClusterSet, KernelKind, Shard};
use clustercluster::special::logsumexp;
use clustercluster::supercluster::{
    log_prior_eq4, log_prior_eq5, shuffle_log_conditional, two_stage_crp_prior, ShuffleKernel,
};
use clustercluster::testing::check;

#[test]
fn prop_eq4_equals_eq5() {
    // the paper's cancellation identity on random configurations
    check(
        "eq4 == eq5",
        40,
        1,
        |rng| {
            let k = 1 + rng.next_below(5) as usize;
            let alpha = 0.2 + 5.0 * rng.next_f64();
            let mu = dirichlet(rng, &vec![1.0; k]);
            let n = 5 + rng.next_below(80) as usize;
            let p = two_stage_crp_prior(rng, n, alpha, &mu);
            (alpha, mu, p)
        },
        |(alpha, mu, p)| {
            let a = log_prior_eq4(p, *alpha, mu);
            let b = log_prior_eq5(p, *alpha, mu);
            if (a - b).abs() < 1e-7 {
                Ok(())
            } else {
                Err(format!("eq4 {a} != eq5 {b}"))
            }
        },
    );
}

#[test]
fn prop_shuffle_kernels_are_distributions() {
    check(
        "shuffle kernels normalize",
        50,
        2,
        |rng| {
            let k = 1 + rng.next_below(8) as usize;
            let mu = dirichlet(rng, &vec![0.5; k]);
            let alpha = 0.1 + 10.0 * rng.next_f64();
            let jm: Vec<u64> = (0..k).map(|_| rng.next_below(20)).collect();
            (alpha, mu, jm)
        },
        |(alpha, mu, jm)| {
            for kernel in [ShuffleKernel::Exact, ShuffleKernel::PaperEq7] {
                let lw = shuffle_log_conditional(kernel, *alpha, mu, jm);
                let z = logsumexp(&lw);
                if z.abs() > 1e-9 {
                    return Err(format!("{kernel:?} normalizer {z}"));
                }
                if lw.len() != mu.len() {
                    return Err("length mismatch".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_suffstats_add_remove_inverse() {
    check(
        "add/remove inverse",
        30,
        3,
        |rng| {
            let d = 1 + rng.next_below(100) as usize;
            let n = 2 + rng.next_below(30) as usize;
            let mut m = BinMat::zeros(n, d);
            for r in 0..n {
                for c in 0..d {
                    if rng.next_f64() < 0.5 {
                        m.set(r, c, true);
                    }
                }
            }
            let order: Vec<usize> = (0..n).collect();
            (m, order)
        },
        |(m, order)| {
            let d = m.dims();
            let mut c = ClusterStats::empty(d);
            for &r in order {
                c.add(m, r);
            }
            // remove in a scrambled order, then re-add — stats identical
            let snapshot = (c.n(), c.ones().to_vec());
            for &r in order.iter().rev() {
                c.remove(m, r);
            }
            if c.n() != 0 || c.ones().iter().any(|&x| x != 0) {
                return Err("empty-state not reached".into());
            }
            for &r in order {
                c.add(m, r);
            }
            if (c.n(), c.ones().to_vec()) != snapshot {
                return Err("roundtrip changed stats".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cached_score_equals_uncached() {
    check(
        "cached == uncached scoring",
        25,
        4,
        |rng| {
            let d = 1 + rng.next_below(80) as usize;
            let n = 3 + rng.next_below(20) as usize;
            let beta = 0.05 + 2.0 * rng.next_f64();
            let mut m = BinMat::zeros(n, d);
            for r in 0..n {
                for c in 0..d {
                    if rng.next_f64() < rng.next_f64() {
                        m.set(r, c, true);
                    }
                }
            }
            (m, beta)
        },
        |(m, beta)| {
            let model = Model::bernoulli(m.dims(), *beta);
            let mut c = ClusterStats::empty(m.dims());
            for r in 0..m.rows() - 1 {
                c.add(m, r);
            }
            let r = m.rows() - 1;
            let cached = c.score(&model, m, r);
            let plain = c.score_uncached(&model, m, r);
            if (cached - plain).abs() < 1e-9 {
                Ok(())
            } else {
                Err(format!("{cached} vs {plain}"))
            }
        },
    );
}

#[test]
fn prop_unpack_block_matches_bits() {
    check(
        "unpack_block_f32 contract",
        25,
        5,
        |rng| {
            let d = 1 + rng.next_below(130) as usize;
            let n = 1 + rng.next_below(20) as usize;
            let mut m = BinMat::zeros(n, d);
            for r in 0..n {
                for c in 0..d {
                    if rng.next_f64() < 0.4 {
                        m.set(r, c, true);
                    }
                }
            }
            let start = rng.next_below(n as u64) as usize;
            let len = 1 + rng.next_below(8) as usize;
            let d_out = d + rng.next_below(70) as usize;
            (m, start, len, d_out)
        },
        |(m, start, len, d_out)| {
            let mut buf = vec![7.0f32; len * d_out];
            m.unpack_block_f32(*start, *len, *d_out, &mut buf);
            for i in 0..*len {
                for c in 0..*d_out {
                    let want = if *start + i < m.rows() && c < m.dims() && m.get(*start + i, c) {
                        1.0
                    } else {
                        0.0
                    };
                    if buf[i * d_out + c] != want {
                        return Err(format!("({i},{c}) = {}", buf[i * d_out + c]));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_coordinator_rounds_preserve_data_integrity() {
    check(
        "coordinator integrity across random configs",
        8,
        6,
        |rng| {
            let k = 1 + rng.next_below(6) as usize;
            let n = 50 + rng.next_below(200) as usize;
            let seed = rng.next_u64();
            (k, n, seed)
        },
        |&(k, n, seed)| {
            let ds = SyntheticConfig {
                n,
                d: 16,
                clusters: 4,
                beta: 0.2,
                seed,
            }
            .generate_with_test_fraction(0.0);
            let cfg = CoordinatorConfig {
                workers: k,
                comm: CommModel::free(),
                update_beta: true,
                ..Default::default()
            };
            let mut rng = Pcg64::seed_from(seed ^ 0xabc);
            let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
            for _ in 0..3 {
                coord.step(&mut rng);
                coord.check_invariants().map_err(|e| e)?;
            }
            // assignments are a complete labeling
            let z = coord.assignments();
            if z.len() != ds.train.rows() {
                return Err("assignment length mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_predictive_density_agrees_oracle_vs_scorer() {
    // The coordinator's Scorer-trait predictive (built on the ClusterSet
    // packed [D, J] weight export) equals an exact-f64 inline mixture
    // oracle on random chains.
    check(
        "oracle == scorer predictive",
        6,
        7,
        |rng| rng.next_u64(),
        |&seed| {
            let ds = SyntheticConfig {
                n: 300,
                d: 24,
                clusters: 4,
                beta: 0.2,
                seed,
            }
            .generate();
            let cfg = CoordinatorConfig {
                workers: 3,
                comm: CommModel::free(),
                ..Default::default()
            };
            let mut rng = Pcg64::seed_from(seed);
            let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
            for _ in 0..3 {
                coord.step(&mut rng);
            }
            let mut scorer = FallbackScorer::new();
            let via_scorer = coord.predictive_loglik(&ds.test, &mut scorer);
            let oracle =
                clustercluster::testing::coordinator_predictive_oracle(&coord, &ds.test);
            if (via_scorer - oracle).abs() < 1e-3 {
                Ok(())
            } else {
                Err(format!("scorer {via_scorer} vs oracle {oracle}"))
            }
        },
    );
}

#[test]
fn prop_cluster_set_slot_reuse_and_compaction() {
    // randomized add/remove sequences against a reference membership
    // model: slot bookkeeping stays exact, freed slots are reused before
    // the store grows, and the slot vector never exceeds the peak number
    // of concurrently-live clusters
    check(
        "cluster-set slot machine",
        20,
        9,
        |rng| {
            let d = 1 + rng.next_below(40) as usize;
            let n = 5 + rng.next_below(60) as usize;
            let mut m = BinMat::zeros(n, d);
            for r in 0..n {
                for c in 0..d {
                    if rng.next_f64() < 0.4 {
                        m.set(r, c, true);
                    }
                }
            }
            (m, rng.next_u64())
        },
        |(m, seed)| {
            let mut rng = Pcg64::seed_from(*seed);
            let mut cs = ClusterSet::new(m.dims());
            let mut members: Vec<Vec<usize>> = Vec::new(); // reference: slot -> rows
            let mut live: Vec<usize> = Vec::new();
            let mut peak_live = 0usize;
            for step in 0..400 {
                let grow = live.is_empty() || rng.next_f64() < 0.55;
                if grow {
                    let r = rng.next_below(m.rows() as u64) as usize;
                    let slot = if live.is_empty() || rng.next_f64() < 0.3 {
                        let s = cs.alloc_empty();
                        if members.len() <= s {
                            members.resize(s + 1, Vec::new());
                        }
                        if !members[s].is_empty() {
                            return Err(format!(
                                "step {step}: allocator handed out slot {s} that still has members"
                            ));
                        }
                        live.push(s);
                        s
                    } else {
                        live[rng.next_below(live.len() as u64) as usize]
                    };
                    cs.add_row(slot, m, r);
                    members[slot].push(r);
                } else {
                    let li = rng.next_below(live.len() as u64) as usize;
                    let slot = live[li];
                    let mi = rng.next_below(members[slot].len() as u64) as usize;
                    let r = members[slot].swap_remove(mi);
                    cs.remove_row(slot, m, r);
                    if members[slot].is_empty() {
                        live.swap_remove(li);
                    }
                }
                peak_live = peak_live.max(live.len());
                cs.check_slot_invariants()
                    .map_err(|e| format!("step {step}: {e}"))?;
                if cs.num_active() != live.len() {
                    return Err(format!(
                        "step {step}: {} active vs reference {}",
                        cs.num_active(),
                        live.len()
                    ));
                }
                if cs.num_slots() > peak_live {
                    return Err(format!(
                        "step {step}: {} slots exceeds peak {} live clusters — free-slot reuse broken",
                        cs.num_slots(),
                        peak_live
                    ));
                }
                if cs.num_slots() - cs.num_active() != cs.num_free() {
                    return Err(format!("step {step}: free-list length inconsistent"));
                }
            }
            // surviving stats match the reference memberships exactly
            for &slot in &live {
                let c = cs.get(slot).ok_or_else(|| format!("live slot {slot} missing"))?;
                if c.n() as usize != members[slot].len() {
                    return Err(format!(
                        "slot {slot}: n={} vs reference {}",
                        c.n(),
                        members[slot].len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cluster_set_keep_slot_then_compact() {
    // the Walker-sweep protocol: remove_row_keep_slot may leave empty
    // live slots mid-sweep; compact_free_slots must restore the full
    // invariant and free exactly the emptied slots
    check(
        "keep-slot + compaction",
        20,
        11,
        |rng| {
            let d = 1 + rng.next_below(16) as usize;
            let n = 4 + rng.next_below(30) as usize;
            let mut m = BinMat::zeros(n, d);
            for r in 0..n {
                for c in 0..d {
                    if rng.next_f64() < 0.5 {
                        m.set(r, c, true);
                    }
                }
            }
            let k = 1 + rng.next_below(6) as usize;
            (m, k, rng.next_u64())
        },
        |(m, k, seed)| {
            let mut rng = Pcg64::seed_from(*seed);
            let mut cs = ClusterSet::new(m.dims());
            let mut slot_of = vec![0usize; m.rows()];
            for r in 0..m.rows() {
                let s = (rng.next_below(*k as u64) as usize).min(cs.num_slots());
                let slot = if s == cs.num_slots() { cs.alloc_empty() } else { s };
                cs.add_row(slot, m, r);
                slot_of[r] = slot;
            }
            let before_active = cs.num_active();
            // empty some clusters via keep-slot removal
            let victim = rng.next_below(cs.num_slots() as u64) as usize;
            let mut emptied = 0usize;
            if cs.get(victim).is_some() {
                for r in 0..m.rows() {
                    if slot_of[r] == victim {
                        cs.remove_row_keep_slot(victim, m, r);
                    }
                }
                emptied = 1;
            }
            cs.compact_free_slots();
            cs.check_slot_invariants()?;
            if cs.num_active() != before_active - emptied {
                return Err(format!(
                    "active {} after emptying {emptied} of {before_active}",
                    cs.num_active()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_split_then_merge_restores_stats_bit_exactly() {
    // the split–merge kernel's state contract: splitting a cluster (a
    // sequence of move_row calls into a fresh slot) and then merging it
    // back (merge_slots) restores the sufficient statistics BIT-exactly
    // — integer counts make the roundtrip an exact inverse — and the
    // slot/free-list machinery ends where it started
    check(
        "split/merge roundtrip",
        25,
        13,
        |rng| {
            let d = 1 + rng.next_below(40) as usize;
            let n = 3 + rng.next_below(40) as usize;
            let mut m = BinMat::zeros(n, d);
            for r in 0..n {
                for c in 0..d {
                    if rng.next_f64() < 0.45 {
                        m.set(r, c, true);
                    }
                }
            }
            (m, rng.next_u64())
        },
        |(m, seed)| {
            let mut rng = Pcg64::seed_from(*seed);
            let n = m.rows();
            let mut cs = ClusterSet::new(m.dims());
            let src = cs.alloc_empty();
            for r in 0..n {
                cs.add_row(src, m, r);
            }
            let snap_n = cs.get(src).unwrap().n();
            let snap_ones = cs.get(src).unwrap().ones().to_vec();
            let slots_before = cs.num_slots();
            let free_before = cs.num_free();
            // split: a random proper subset moves to a fresh slot
            let dst = cs.alloc_empty();
            let mut moved = 0usize;
            for r in 0..n - 1 {
                if rng.next_f64() < 0.5 {
                    cs.move_row(src, dst, m, r);
                    moved += 1;
                }
            }
            if moved == 0 {
                cs.move_row(src, dst, m, 0);
                moved = 1;
            }
            cs.check_slot_invariants()?;
            if cs.num_active() != 2 {
                return Err(format!("expected 2 live clusters, got {}", cs.num_active()));
            }
            let split_total = cs.get(src).unwrap().n() + cs.get(dst).unwrap().n();
            if split_total != snap_n {
                return Err(format!("split lost mass: {split_total} vs {snap_n}"));
            }
            // merge back: stats must be bit-identical to the snapshot
            cs.merge_slots(dst, src);
            cs.check_slot_invariants()?;
            let got = cs.get(src).ok_or("src died in the merge")?;
            if got.n() != snap_n {
                return Err(format!("n drifted: {} vs {snap_n}", got.n()));
            }
            if got.ones() != &snap_ones[..] {
                return Err("one-counts drifted across the split/merge roundtrip".into());
            }
            if cs.num_slots() != slots_before + 1 {
                return Err(format!(
                    "slot vector should hold exactly the split slot extra: {} vs {}",
                    cs.num_slots(),
                    slots_before + 1
                ));
            }
            if cs.num_free() != free_before + 1 {
                return Err(format!(
                    "free list should gain exactly the merged-away slot: {} vs {}",
                    cs.num_free(),
                    free_before + 1
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_split_merge_composite_sweeps_preserve_shard_invariants() {
    // arbitrary interleavings of ALL four kernels — including the
    // split–merge composites' accept/reject/rollback paths — keep the
    // full data/stats/slot invariants on one shard
    check(
        "split-merge composite interleaving",
        6,
        14,
        |rng| rng.next_u64(),
        |&seed| {
            let ds = SyntheticConfig {
                n: 70 + (seed % 50) as usize,
                d: 12,
                clusters: 3,
                beta: 0.2,
                seed,
            }
            .generate_with_test_fraction(0.0);
            let mut model = Model::bernoulli(12, 0.5);
            model.build_lut(ds.train.rows() + 1);
            let rows: Vec<usize> = (0..ds.train.rows()).collect();
            let mut sh = Shard::init_from_prior(&ds.train, rows, 1.2, Pcg64::seed_from(seed));
            let mut pick = Pcg64::seed_from(seed ^ 0xbeef);
            let kinds = [
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
                KernelKind::SplitMergeGibbs,
                KernelKind::SplitMergeWalker,
            ];
            for step in 0..8 {
                let kind = kinds[pick.next_below(kinds.len() as u64) as usize];
                kind.kernel().sweep(&mut sh, (&ds.train).into(), &model);
                sh.check_invariants(&ds.train)
                    .map_err(|e| format!("step {step} ({kind:?}): {e}"))?;
                if sh.num_rows() != ds.train.rows() {
                    return Err(format!("step {step}: rows not conserved"));
                }
            }
            // deterministically exercise the move layer at least once
            KernelKind::SplitMergeGibbs
                .kernel()
                .sweep(&mut sh, (&ds.train).into(), &model);
            sh.check_invariants(&ds.train)
                .map_err(|e| format!("final split-merge sweep: {e}"))?;
            let (proposals, _, _) = sh.split_merge_stats();
            if proposals == 0 {
                return Err("no split-merge proposal ever ran".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shard_kernel_interleaving_preserves_invariants() {
    // arbitrary interleavings of the two kernels on one shard keep the
    // full data/stats/slot invariants — the kernels share one state
    // contract, so they must compose
    check(
        "shard kernel interleaving",
        6,
        12,
        |rng| rng.next_u64(),
        |&seed| {
            let ds = SyntheticConfig {
                n: 80 + (seed % 60) as usize,
                d: 12,
                clusters: 3,
                beta: 0.2,
                seed,
            }
            .generate_with_test_fraction(0.0);
            let mut model = Model::bernoulli(12, 0.5);
            model.build_lut(ds.train.rows() + 1);
            let rows: Vec<usize> = (0..ds.train.rows()).collect();
            let mut sh = Shard::init_from_prior(&ds.train, rows, 1.2, Pcg64::seed_from(seed));
            let mut pick = Pcg64::seed_from(seed ^ 0xfeed);
            for step in 0..8 {
                let kind = if pick.next_f64() < 0.5 {
                    KernelKind::CollapsedGibbs
                } else {
                    KernelKind::WalkerSlice
                };
                kind.kernel().sweep(&mut sh, (&ds.train).into(), &model);
                sh.check_invariants(&ds.train)
                    .map_err(|e| format!("step {step} ({kind:?}): {e}"))?;
                if sh.num_rows() != ds.train.rows() {
                    return Err(format!("step {step}: rows not conserved"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_two_stage_prior_total_mass() {
    // cluster sizes always sum to n; supercluster ids in range
    check(
        "two-stage CRP bookkeeping",
        40,
        8,
        |rng| {
            let k = 1 + rng.next_below(6) as usize;
            let n = 1 + rng.next_below(120) as usize;
            let alpha = 0.1 + 8.0 * rng.next_f64();
            let mu = dirichlet(rng, &vec![1.0; k]);
            let p = two_stage_crp_prior(rng, n, alpha, &mu);
            (n, k, p)
        },
        |(n, k, p)| {
            if p.cluster_sizes().iter().sum::<u64>() != *n as u64 {
                return Err("sizes don't sum to n".into());
            }
            if p.s.iter().any(|&s| s as usize >= *k) {
                return Err("supercluster id out of range".into());
            }
            if p.z.iter().any(|&z| z as usize >= p.num_clusters()) {
                return Err("cluster id out of range".into());
            }
            Ok(())
        },
    );
}
