//! The scorer-equivalence gate for the batched sweep path.
//!
//! Three suites:
//!
//! 1. **Bit-identity.** A sweep whose candidate scoring runs through the
//!    batched `Scorer::score_rows_against_clusters` dispatch must be
//!    *bit-identical* — same RNG stream, same assignments, same α bits —
//!    to the pre-refactor scalar per-cluster path, on fixed seeds, for
//!    every kernel (including the split–merge composites, whose
//!    restricted scans share the dispatch), from both entry points
//!    (serial and the K=3 coordinator with shuffling). The packed
//!    tables are copied from the
//!    same `ClusterStats` caches the scalar path reads and the default
//!    scorer adds the same f64 terms in the same order, so any
//!    divergence is a real dispatch bug, not float noise.
//!
//! 2. **Incremental-maintenance drift.** The move-only packed-table
//!    engine (DESIGN.md §8) must be bit-identical over full chains to
//!    the eager per-datum repack reference (`Shard::set_eager_repack`);
//!    the table-level counterpart (randomized join/leave/alloc/free vs
//!    from-scratch repack, bit-equal) lives in
//!    `rust/src/sampler/score.rs` unit tests.
//!
//! 3. **Padding contract.** Property tests (previously asserted only in
//!    the Python L1/L2 suites) for the `Scorer` padding rules against
//!    `FallbackScorer`: padded dims with `W1 = W0 = 0` are an exact
//!    no-op, padded clusters at `logpi = -1e30` never win the logsumexp,
//!    padded rows never perturb real rows.

use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::data::BinMat;
use clustercluster::mapreduce::CommModel;
use clustercluster::model::{ClusterStats, Model};
use clustercluster::rng::Pcg64;
use clustercluster::runtime::{FallbackScorer, Scorer, ScorerKind};
use clustercluster::sampler::{KernelAssignment, KernelKind, ScoreMode};
use clustercluster::serial::{SerialConfig, SerialGibbs};
use clustercluster::testing::check;

// ---------------------------------------------------------------------
// 1. scalar vs batched bit-identity
// ---------------------------------------------------------------------

fn equivalence_dataset(seed: u64) -> clustercluster::data::Dataset {
    SyntheticConfig {
        n: 160,
        d: 16,
        clusters: 3,
        beta: 0.15,
        seed,
    }
    .generate_with_test_fraction(0.0)
}

/// Serial chain: the batched dispatch must reproduce the scalar chain
/// sweep-by-sweep, bit for bit (raw slot assignments, not just the
/// partition, and the exact α bits — i.e. the RNG streams never
/// diverge).
fn assert_serial_bit_identical(kernel: KernelKind) {
    let ds = equivalence_dataset(21);
    let mk = |scoring: ScoreMode| SerialConfig {
        update_alpha: true,
        update_beta: true,
        kernel,
        scoring,
        ..Default::default()
    };
    let mut rng_s = Pcg64::seed_from(77);
    let mut scalar = SerialGibbs::init_from_prior(&ds.train, mk(ScoreMode::Scalar), &mut rng_s);
    let mut rng_b = Pcg64::seed_from(77);
    let mut batched = SerialGibbs::init_from_prior(
        &ds.train,
        mk(ScoreMode::Batched(ScorerKind::Fallback)),
        &mut rng_b,
    );
    assert_eq!(
        scalar.assignments(),
        batched.assignments(),
        "prior initializations diverged ({kernel:?})"
    );
    for it in 0..40 {
        scalar.sweep(&mut rng_s);
        batched.sweep(&mut rng_b);
        assert_eq!(
            scalar.assignments(),
            batched.assignments(),
            "assignments diverged at sweep {it} ({kernel:?})"
        );
        assert_eq!(
            scalar.alpha().to_bits(),
            batched.alpha().to_bits(),
            "α diverged at sweep {it} ({kernel:?}): {} vs {}",
            scalar.alpha(),
            batched.alpha()
        );
        let (sb, bb) = (scalar.model.as_bernoulli(), batched.model.as_bernoulli());
        for (a, b) in sb.beta.iter().zip(&bb.beta) {
            assert_eq!(a.to_bits(), b.to_bits(), "β diverged at sweep {it} ({kernel:?})");
        }
    }
    scalar.check_invariants().unwrap();
    batched.check_invariants().unwrap();
}

#[test]
fn serial_collapsed_gibbs_batched_is_bit_identical_to_scalar() {
    assert_serial_bit_identical(KernelKind::CollapsedGibbs);
}

#[test]
fn serial_walker_slice_batched_is_bit_identical_to_scalar() {
    assert_serial_bit_identical(KernelKind::WalkerSlice);
}

#[test]
fn serial_split_merge_batched_is_bit_identical_to_scalar() {
    // the split–merge composite's restricted scans score through the
    // same dispatch as the per-datum sweeps, so the whole composite
    // chain — launch coin flips, scan picks, MH accepts — must be
    // bit-identical across dispatches too
    assert_serial_bit_identical(KernelKind::SplitMergeGibbs);
}

/// K=3 coordinator with shuffling: the batched dispatch inside the map
/// step must leave the whole distributed chain bit-identical.
fn assert_coordinator_bit_identical(kernel: KernelKind) {
    let ds = equivalence_dataset(22);
    let mk = |scoring: ScoreMode| CoordinatorConfig {
        workers: 3,
        local_sweeps: 2,
        update_alpha: true,
        update_beta: true,
        shuffle: true,
        kernel_assignment: KernelAssignment::AllSame(kernel),
        scoring,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng_s = Pcg64::seed_from(99);
    let mut scalar = Coordinator::new(&ds.train, mk(ScoreMode::Scalar), &mut rng_s);
    let mut rng_b = Pcg64::seed_from(99);
    let mut batched = Coordinator::new(
        &ds.train,
        mk(ScoreMode::Batched(ScorerKind::Fallback)),
        &mut rng_b,
    );
    for it in 0..25 {
        scalar.step(&mut rng_s);
        batched.step(&mut rng_b);
        assert_eq!(
            scalar.assignments(),
            batched.assignments(),
            "assignments diverged at round {it} ({kernel:?})"
        );
        assert_eq!(
            scalar.alpha().to_bits(),
            batched.alpha().to_bits(),
            "α diverged at round {it} ({kernel:?})"
        );
    }
    scalar.check_invariants().unwrap();
    batched.check_invariants().unwrap();
}

#[test]
fn coordinator_k3_collapsed_gibbs_batched_is_bit_identical() {
    assert_coordinator_bit_identical(KernelKind::CollapsedGibbs);
}

#[test]
fn coordinator_k3_walker_slice_batched_is_bit_identical() {
    assert_coordinator_bit_identical(KernelKind::WalkerSlice);
}

#[test]
fn coordinator_k3_split_merge_walker_batched_is_bit_identical() {
    assert_coordinator_bit_identical(KernelKind::SplitMergeWalker);
}

/// Chain-level drift gate for the incremental packed-table engine: the
/// move-only maintenance (zero table work on self-moves, held-out
/// correction from the cluster cache) must be *bit-identical* over full
/// chains to the eager per-datum repack reference — same raw slot
/// assignments, same α/β bits, never-diverging RNG streams. Any packed
/// column left stale by the move-only bookkeeping would flip a
/// categorical pick within a few sweeps here.
fn assert_incremental_matches_eager(kernel: KernelKind) {
    let ds = equivalence_dataset(23);
    let mk = || SerialConfig {
        update_alpha: true,
        update_beta: true,
        kernel,
        scoring: ScoreMode::Batched(ScorerKind::Fallback),
        ..Default::default()
    };
    let mut rng_i = Pcg64::seed_from(55);
    let mut incremental = SerialGibbs::init_from_prior(&ds.train, mk(), &mut rng_i);
    let mut rng_e = Pcg64::seed_from(55);
    let mut eager = SerialGibbs::init_from_prior(&ds.train, mk(), &mut rng_e);
    eager.set_eager_repack(true);
    for it in 0..40 {
        incremental.sweep(&mut rng_i);
        eager.sweep(&mut rng_e);
        assert_eq!(
            incremental.assignments(),
            eager.assignments(),
            "incremental vs eager diverged at sweep {it} ({kernel:?})"
        );
        assert_eq!(
            incremental.alpha().to_bits(),
            eager.alpha().to_bits(),
            "α diverged at sweep {it} ({kernel:?})"
        );
        let (ib, eb) = (incremental.model.as_bernoulli(), eager.model.as_bernoulli());
        for (a, b) in ib.beta.iter().zip(&eb.beta) {
            assert_eq!(a.to_bits(), b.to_bits(), "β diverged at sweep {it} ({kernel:?})");
        }
    }
    incremental.check_invariants().unwrap();
    eager.check_invariants().unwrap();
}

#[test]
fn incremental_tables_match_eager_repack_collapsed_gibbs() {
    assert_incremental_matches_eager(KernelKind::CollapsedGibbs);
}

#[test]
fn incremental_tables_match_eager_repack_walker_slice() {
    assert_incremental_matches_eager(KernelKind::WalkerSlice);
}

#[test]
fn incremental_tables_match_eager_repack_split_merge() {
    // the move layer's two-column invalidations (and its rollbacks) must
    // keep the move-only tables bit-identical to the eager reference
    // over full composite chains
    assert_incremental_matches_eager(KernelKind::SplitMergeGibbs);
}

// ---------------------------------------------------------------------
// 2. Scorer padding-contract property tests
// ---------------------------------------------------------------------

fn rand_problem(
    rng: &mut Pcg64,
    n: usize,
    d: usize,
    j: usize,
) -> (BinMat, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut m = BinMat::zeros(n, d);
    for r in 0..n {
        for c in 0..d {
            if rng.next_f64() < 0.4 {
                m.set(r, c, true);
            }
        }
    }
    let mut w1 = vec![0.0f32; d * j];
    let mut w0 = vec![0.0f32; d * j];
    for i in 0..d * j {
        let p = 0.05 + 0.9 * rng.next_f64();
        w1[i] = (p as f32).ln();
        w0[i] = (1.0 - p as f32).ln();
    }
    let mut logpi = vec![0.0f32; j];
    let mut total = 0.0f64;
    let mut raw = vec![0.0f64; j];
    for x in raw.iter_mut() {
        *x = 0.1 + rng.next_f64();
        total += *x;
    }
    for (jj, x) in raw.iter().enumerate() {
        logpi[jj] = ((x / total).ln()) as f32;
    }
    (m, w1, w0, logpi)
}

#[test]
fn prop_padded_dims_are_a_noop() {
    // pad dims d -> d_v with W1 = W0 = 0 (log 1): exact no-op
    check(
        "dim padding no-op",
        25,
        41,
        |rng| {
            let n = 1 + rng.next_below(12) as usize;
            let d = 1 + rng.next_below(90) as usize;
            let j = 1 + rng.next_below(12) as usize;
            let pad = 1 + rng.next_below(70) as usize;
            let (m, w1, w0, logpi) = rand_problem(rng, n, d, j);
            (m, w1, w0, logpi, d, j, pad)
        },
        |(m, w1, w0, logpi, d, j, pad)| {
            let (d, j, pad) = (*d, *j, *pad);
            let mut s = FallbackScorer::new();
            let base = s.predictive_density(m, w1, w0, logpi, d, j);
            // [D, J] row-major: dim padding appends zero rows
            let dv = d + pad;
            let mut w1p = w1.clone();
            let mut w0p = w0.clone();
            w1p.resize(dv * j, 0.0);
            w0p.resize(dv * j, 0.0);
            let padded = s.predictive_density(m, &w1p, &w0p, logpi, dv, j);
            for r in 0..m.rows() {
                if (padded[r] - base[r]).abs() > 1e-6 {
                    return Err(format!("row {r}: {} vs {}", padded[r], base[r]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_padded_clusters_never_win() {
    // pad clusters j -> j_v at logpi = -1e30, with ARBITRARY weight
    // columns in the pad: the masked columns must never contribute
    check(
        "cluster padding masked",
        25,
        42,
        |rng| {
            let n = 1 + rng.next_below(10) as usize;
            let d = 1 + rng.next_below(60) as usize;
            let j = 1 + rng.next_below(10) as usize;
            let pad = 1 + rng.next_below(10) as usize;
            let (m, w1, w0, logpi) = rand_problem(rng, n, d, j);
            // garbage (but finite) weights for the padded columns
            let (_, g1, g0, _) = rand_problem(rng, 1, d, pad);
            (m, w1, w0, logpi, d, j, pad, g1, g0)
        },
        |(m, w1, w0, logpi, d, j, pad, g1, g0)| {
            let (d, j, pad) = (*d, *j, *pad);
            let jv = j + pad;
            let mut s = FallbackScorer::new();
            let base = s.predictive_density(m, w1, w0, logpi, d, j);
            let mut w1p = vec![0.0f32; d * jv];
            let mut w0p = vec![0.0f32; d * jv];
            for dd in 0..d {
                w1p[dd * jv..dd * jv + j].copy_from_slice(&w1[dd * j..(dd + 1) * j]);
                w0p[dd * jv..dd * jv + j].copy_from_slice(&w0[dd * j..(dd + 1) * j]);
                w1p[dd * jv + j..(dd + 1) * jv].copy_from_slice(&g1[dd * pad..(dd + 1) * pad]);
                w0p[dd * jv + j..(dd + 1) * jv].copy_from_slice(&g0[dd * pad..(dd + 1) * pad]);
            }
            let mut logpip = vec![-1.0e30f32; jv];
            logpip[..j].copy_from_slice(logpi);
            let padded = s.predictive_density(m, &w1p, &w0p, &logpip, d, jv);
            for r in 0..m.rows() {
                if (padded[r] - base[r]).abs() > 1e-5 {
                    return Err(format!("row {r}: {} vs {}", padded[r], base[r]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_padded_rows_do_not_perturb_real_rows() {
    // appending zero pad rows to the batch leaves every real row's
    // output bit-identical (rows are scored independently)
    check(
        "row padding inert",
        25,
        43,
        |rng| {
            let n = 1 + rng.next_below(12) as usize;
            let d = 1 + rng.next_below(80) as usize;
            let j = 1 + rng.next_below(12) as usize;
            let pad = 1 + rng.next_below(12) as usize;
            let (m, w1, w0, logpi) = rand_problem(rng, n, d, j);
            (m, w1, w0, logpi, d, j, pad)
        },
        |(m, w1, w0, logpi, d, j, pad)| {
            let (d, j, pad) = (*d, *j, *pad);
            let n = m.rows();
            let mut s = FallbackScorer::new();
            let base = s.predictive_density(m, w1, w0, logpi, d, j);
            let mut mp = BinMat::zeros(n + pad, d);
            for r in 0..n {
                m.for_each_one(r, |dd| mp.set(r, dd, true));
            }
            let padded = s.predictive_density(&mp, w1, w0, logpi, d, j);
            if padded.len() != n + pad {
                return Err("padded output length".into());
            }
            for r in 0..n {
                if padded[r].to_bits() != base[r].to_bits() {
                    return Err(format!("row {r}: {} vs {}", padded[r], base[r]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batched_block_matches_cluster_cache_scoring() {
    // the sweep-side entry point: packing cached (bias, diff) tables and
    // scoring through Scorer::score_rows_against_clusters reproduces the
    // per-cluster scalar scores bit-for-bit, and dim padding (diff = 0)
    // stays an exact no-op
    check(
        "batched block == scalar cluster scores",
        20,
        44,
        |rng| {
            let n = 4 + rng.next_below(16) as usize;
            let d = 1 + rng.next_below(50) as usize;
            let j = 1 + rng.next_below(8) as usize;
            let beta = 0.05 + 2.0 * rng.next_f64();
            let mut m = BinMat::zeros(n, d);
            for r in 0..n {
                for c in 0..d {
                    if rng.next_f64() < 0.5 {
                        m.set(r, c, true);
                    }
                }
            }
            (m, j, beta, rng.next_u64())
        },
        |(m, j, beta, seed)| {
            let (j, beta) = (*j, *beta);
            let d = m.dims();
            let model = Model::bernoulli(d, beta);
            let mut rng = Pcg64::seed_from(*seed);
            let mut clusters: Vec<ClusterStats> =
                (0..j).map(|_| ClusterStats::empty(d)).collect();
            for r in 0..m.rows() {
                let c = rng.next_below(j as u64) as usize;
                clusters[c].add(m, r);
            }
            // pack [D, J] bias/diff from the same caches scalar reads,
            // with one extra padded dim row of zeros (exact no-op)
            let dv = d + 1;
            let mut bias = vec![0.0f64; j];
            let mut diff = vec![0.0f64; dv * j];
            for (jj, c) in clusters.iter_mut().enumerate() {
                let (b, _aux, dtab) = c.cached_table(&model);
                bias[jj] = b;
                for (dd, &v) in dtab.iter().enumerate() {
                    diff[dd * j + jj] = v;
                }
            }
            let rows: Vec<usize> = (0..m.rows()).collect();
            let mut s = FallbackScorer::new();
            let mut block = Vec::new();
            s.score_rows_against_clusters(m, &rows, &bias, &diff, dv, j, &mut block);
            if block.len() != m.rows() * j {
                return Err("block shape".into());
            }
            for (ri, &r) in rows.iter().enumerate() {
                for (jj, c) in clusters.iter_mut().enumerate() {
                    let want = c.score(&model, m, r);
                    let got = block[ri * j + jj];
                    if got.to_bits() != want.to_bits() {
                        return Err(format!("({r},{jj}): {got} vs {want}"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// 3. the exported [D, J] weight columns feed the trait path correctly
// ---------------------------------------------------------------------

#[test]
fn coordinator_trait_predictive_matches_inline_oracle() {
    let ds = SyntheticConfig {
        n: 300,
        d: 24,
        clusters: 4,
        beta: 0.2,
        seed: 51,
    }
    .generate();
    let cfg = CoordinatorConfig {
        workers: 3,
        comm: CommModel::free(),
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(51);
    let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
    for _ in 0..3 {
        coord.step(&mut rng);
    }
    let mut scorer = FallbackScorer::new();
    let via_trait = coord.predictive_loglik(&ds.test, &mut scorer);
    let oracle = clustercluster::testing::coordinator_predictive_oracle(&coord, &ds.test);
    assert!(
        (via_trait - oracle).abs() < 1e-3,
        "trait {via_trait} vs oracle {oracle}"
    );
}
