//! Protocol-fuzz gate for the serving layer (`rust/src/serve/`).
//!
//! Two layers of the same guarantee:
//!
//! 1. **Pure codec fuzz** — `decode_request` / `decode_response` are
//!    hammered with every truncated prefix, every single-bit flip, and
//!    seeded random garbage derived from every valid frame. Each call
//!    runs under `catch_unwind`: the codec must return `Ok` or a
//!    `ProtoError`, never panic. This is exhaustive because the codec
//!    is a pure function over a byte slice.
//! 2. **Loopback fuzz** — the same malformed bytes go to a live server
//!    over TCP. Every case must end in a clean error response and/or a
//!    disconnect, never a hang (client reads run under a timeout and a
//!    timeout fails the test) and never a dead server (the suite
//!    re-pings after every hostile batch).
//!
//! The hostile length-prefix case pins the no-OOM contract: a header
//! claiming `u32::MAX` bytes is rejected *before* any allocation.

use std::io::ErrorKind;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use clustercluster::coordinator::CoordinatorConfig;
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::rng::Pcg64;
use clustercluster::serve::protocol::{
    decode_request, decode_response, encode_request, encode_response, validate_frame_len,
    AssignBody, DensityBody, Request, Response, RowBits, ScoreBody, StatsBody, MAX_FRAME,
    OP_INSERT,
};
use clustercluster::serve::{spawn, Client, ServeConfig, ServeHandle};

// ---------------------------------------------------------------------------
// corpus

fn request_corpus() -> Vec<Request> {
    let narrow = RowBits::from_ones(5, &[0, 4]);
    let wide = RowBits::from_ones(70, &[0, 31, 63, 64, 69]);
    vec![
        Request::Ping,
        Request::Stats,
        Request::Score(narrow.clone()),
        Request::Score(wide.clone()),
        Request::Assign(narrow.clone()),
        Request::Density(wide),
        Request::Insert(narrow),
        Request::Delete(0),
        Request::Delete(u64::MAX),
        Request::Shutdown,
    ]
}

fn response_corpus() -> Vec<Response> {
    vec![
        Response::Pong,
        Response::Stats(StatsBody {
            round: 3,
            rows: 100,
            dims: 16,
            clusters: 7,
            alpha: 0.8,
            queries: 12,
        }),
        Response::Score(ScoreBody {
            round: 2,
            log_pred_empty: -11.09,
            scores: vec![-3.0, -7.5, f64::NEG_INFINITY, 0.0],
        }),
        Response::Assign(AssignBody {
            round: 2,
            cluster: -1,
            log_weight: -9.25,
        }),
        Response::Density(DensityBody {
            round: 9,
            log_density: -12.5,
        }),
        Response::Queued {
            op: OP_INSERT,
            row: 100,
        },
        Response::ShuttingDown,
        Response::Error("boom".to_string()),
    ]
}

/// Decode must be total: `Ok` or `Err`, never a panic, on any bytes.
fn assert_decodes_totally(bytes: &[u8], what: &str) {
    let b = bytes.to_vec();
    let r = catch_unwind(AssertUnwindSafe(|| {
        let _ = decode_request(&b);
    }));
    assert!(r.is_ok(), "decode_request panicked on {what}: {bytes:02x?}");
    let b = bytes.to_vec();
    let r = catch_unwind(AssertUnwindSafe(|| {
        let _ = decode_response(&b);
    }));
    assert!(r.is_ok(), "decode_response panicked on {what}: {bytes:02x?}");
}

// ---------------------------------------------------------------------------
// pure codec fuzz

#[test]
fn every_truncated_prefix_decodes_cleanly() {
    for req in request_corpus() {
        let full = encode_request(&req);
        // the full payload must decode back exactly
        assert_eq!(decode_request(&full).unwrap(), req);
        for cut in 0..full.len() {
            let prefix = &full[..cut];
            assert_decodes_totally(prefix, "truncated prefix");
            // a strict prefix of a valid frame is never a valid frame
            // of the same request (no self-delimiting ambiguity)
            if let Ok(got) = decode_request(prefix) {
                assert_ne!(got, req, "prefix of length {cut} decoded as the full request");
            }
        }
    }
    for resp in response_corpus() {
        let full = encode_response(&resp);
        assert_eq!(decode_response(&full).unwrap(), resp);
        for cut in 0..full.len() {
            assert_decodes_totally(&full[..cut], "truncated response prefix");
        }
    }
}

#[test]
fn every_single_bit_flip_decodes_cleanly() {
    for req in request_corpus() {
        let full = encode_request(&req);
        for byte in 0..full.len() {
            for bit in 0..8 {
                let mut flipped = full.clone();
                flipped[byte] ^= 1 << bit;
                assert_decodes_totally(&flipped, "bit flip");
            }
        }
    }
    for resp in response_corpus() {
        let full = encode_response(&resp);
        for byte in 0..full.len() {
            for bit in 0..8 {
                let mut flipped = full.clone();
                flipped[byte] ^= 1 << bit;
                assert_decodes_totally(&flipped, "response bit flip");
            }
        }
    }
}

#[test]
fn random_garbage_decodes_cleanly() {
    let mut rng = Pcg64::seed_from(0xF022);
    for _ in 0..2_000 {
        let len = (rng.next_u64() % 64) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        assert_decodes_totally(&bytes, "random garbage");
    }
    // garbage grafted onto valid opcodes: plausible-looking headers
    // with hostile bodies
    for req in request_corpus() {
        let full = encode_request(&req);
        for _ in 0..200 {
            let keep = (rng.next_u64() as usize) % (full.len() + 1);
            let extra = (rng.next_u64() % 16) as usize;
            let mut bytes = full[..keep].to_vec();
            bytes.extend((0..extra).map(|_| (rng.next_u64() & 0xFF) as u8));
            assert_decodes_totally(&bytes, "grafted garbage");
        }
    }
}

#[test]
fn length_prefix_gate_bounds_allocation() {
    assert!(validate_frame_len(0).is_err());
    assert!(validate_frame_len(1).is_ok());
    assert!(validate_frame_len(MAX_FRAME).is_ok());
    for hostile in [MAX_FRAME + 1, 1 << 24, 1 << 31, u32::MAX] {
        assert!(
            validate_frame_len(hostile).is_err(),
            "hostile length {hostile} passed the gate"
        );
    }
}

// ---------------------------------------------------------------------------
// loopback fuzz against a live server

fn tiny_server() -> ServeHandle {
    let ds = SyntheticConfig {
        n: 48,
        d: 16,
        clusters: 4,
        beta: 0.2,
        seed: 11,
    }
    .generate();
    let ccfg = CoordinatorConfig {
        workers: 2,
        ..Default::default()
    };
    let scfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        rounds: 1, // publish one refined snapshot, then idle: the fuzz
        // batches below measure protocol behavior, not sampling
        seed: 11,
        ..Default::default()
    };
    spawn(ds.train, ccfg, scfg).expect("spawn tiny server")
}

fn ping_ok(addr: &str) {
    let mut c = Client::connect(addr).expect("connect for ping");
    c.set_timeout(Some(Duration::from_secs(5))).unwrap();
    match c.request(&Request::Ping) {
        Ok(Response::Pong) => {}
        other => panic!("server unhealthy after hostile batch: {other:?}"),
    }
}

/// Send raw bytes on a fresh connection, half-close, and drain the
/// server's responses until it disconnects. A read timeout = a hang =
/// test failure; everything else (zero or more well-formed frames, then
/// EOF) is a clean outcome.
fn hostile_exchange(addr: &str, bytes: &[u8]) -> Vec<Response> {
    let mut c = Client::connect(addr).expect("connect");
    c.set_timeout(Some(Duration::from_secs(10))).unwrap();
    c.send_raw(bytes).expect("send raw");
    c.finish_writes().expect("half-close");
    let mut responses = Vec::new();
    loop {
        match c.read_response() {
            Ok(r) => responses.push(r),
            Err(e) => {
                assert!(
                    e.kind() != ErrorKind::WouldBlock && e.kind() != ErrorKind::TimedOut,
                    "server hung on hostile bytes {bytes:02x?}"
                );
                return responses;
            }
        }
    }
}

fn frame_of(payload: &[u8]) -> Vec<u8> {
    let mut out = (payload.len() as u32).to_le_bytes().to_vec();
    out.extend_from_slice(payload);
    out
}

#[test]
fn loopback_truncated_frames_never_kill_the_server() {
    let server = tiny_server();
    let addr = server.addr().to_string();
    for req in request_corpus() {
        if matches!(req, Request::Shutdown) {
            continue; // exercised separately — it stops the server
        }
        let frame = frame_of(&encode_request(&req));
        // every strict prefix of the framed bytes, including the empty
        // send and cuts inside the length header
        for cut in 0..frame.len() {
            let _ = hostile_exchange(&addr, &frame[..cut]);
        }
        ping_ok(&addr);
    }
    server.join().expect("clean shutdown");
}

#[test]
fn loopback_bit_flips_never_kill_the_server() {
    let server = tiny_server();
    let addr = server.addr().to_string();
    for req in request_corpus() {
        if matches!(req, Request::Shutdown) {
            continue;
        }
        let frame = frame_of(&encode_request(&req));
        for byte in 0..frame.len() {
            for bit in 0..8 {
                let mut flipped = frame.clone();
                flipped[byte] ^= 1 << bit;
                let _ = hostile_exchange(&addr, &flipped);
            }
        }
        ping_ok(&addr);
    }
    server.join().expect("clean shutdown");
}

#[test]
fn loopback_random_garbage_never_kills_the_server() {
    let server = tiny_server();
    let addr = server.addr().to_string();
    let mut rng = Pcg64::seed_from(0xBADBAD);
    for _ in 0..64 {
        let len = (rng.next_u64() % 48) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let _ = hostile_exchange(&addr, &bytes);
    }
    ping_ok(&addr);
    server.join().expect("clean shutdown");
}

#[test]
fn loopback_hostile_length_prefix_is_rejected_without_oom() {
    let server = tiny_server();
    let addr = server.addr().to_string();
    for hostile in [0u32, MAX_FRAME + 1, 1 << 30, u32::MAX] {
        let got = hostile_exchange(&addr, &hostile.to_le_bytes());
        // the pre-allocation gate must answer with a framing error
        // (then disconnect) — not silently wait for 4 GiB of body
        assert!(
            got.iter()
                .any(|r| matches!(r, Response::Error(m) if m.contains("frame"))),
            "length {hostile}: expected a framing-error response, got {got:?}"
        );
        ping_ok(&addr);
    }
    server.join().expect("clean shutdown");
}

#[test]
fn loopback_in_frame_decode_error_keeps_the_connection() {
    let server = tiny_server();
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr).expect("connect");
    c.set_timeout(Some(Duration::from_secs(10))).unwrap();
    // well-framed payload with an unknown opcode: decode-level error,
    // the connection must survive and answer a PING afterwards
    c.send_raw(&frame_of(&[0x7Fu8])).unwrap();
    match c.read_response().expect("error response") {
        Response::Error(m) => assert!(m.contains("opcode"), "unexpected error: {m}"),
        other => panic!("expected Error, got {other:?}"),
    }
    match c.request(&Request::Ping).expect("ping on same connection") {
        Response::Pong => {}
        other => panic!("expected Pong, got {other:?}"),
    }
    drop(c);
    server.join().expect("clean shutdown");
}

#[test]
fn shutdown_frame_stops_the_server() {
    let server = tiny_server();
    let addr = server.addr().to_string();
    let mut c = Client::connect(&addr).expect("connect");
    c.set_timeout(Some(Duration::from_secs(10))).unwrap();
    match c.request(&Request::Shutdown).expect("shutdown response") {
        Response::ShuttingDown => {}
        other => panic!("expected ShuttingDown, got {other:?}"),
    }
    server.join().expect("driver exits cleanly after SHUTDOWN");
}
