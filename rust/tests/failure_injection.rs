//! Failure injection: the runtime and IO layers must fail loudly and
//! cleanly on corrupt or missing inputs — no partial loads, no silent
//! wrong numbers.

use clustercluster::data::io::{load_binmat, save_binmat};
use clustercluster::data::BinMat;
use clustercluster::runtime::PjrtScorer;
use std::path::{Path, PathBuf};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("cc_failures").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_an_error() {
    let d = tmpdir("missing");
    let err = PjrtScorer::load(&d).unwrap_err();
    assert!(err.to_string().contains("manifest"), "{err}");
}

#[test]
fn malformed_manifest_line_is_an_error() {
    let d = tmpdir("malformed");
    std::fs::write(d.join("manifest.txt"), "only three fields\n").unwrap();
    let err = PjrtScorer::load(&d).unwrap_err();
    assert!(err.to_string().contains("malformed"), "{err}");
}

#[test]
fn empty_manifest_is_an_error() {
    let d = tmpdir("empty");
    std::fs::write(d.join("manifest.txt"), "# nothing but comments\n\n").unwrap();
    let err = PjrtScorer::load(&d).unwrap_err();
    assert!(err.to_string().contains("no variants"), "{err}");
}

#[test]
fn corrupt_hlo_text_is_an_error() {
    let d = tmpdir("corrupt_hlo");
    std::fs::write(d.join("bad.hlo.txt"), "HloModule this is not valid hlo {{{").unwrap();
    std::fs::write(
        d.join("manifest.txt"),
        "bad loglik 64 256 128 bad.hlo.txt\n",
    )
    .unwrap();
    assert!(PjrtScorer::load(&d).is_err());
}

#[test]
fn manifest_pointing_at_missing_file_is_an_error() {
    let d = tmpdir("dangling");
    std::fs::write(
        d.join("manifest.txt"),
        "ghost loglik 64 256 128 ghost.hlo.txt\n",
    )
    .unwrap();
    assert!(PjrtScorer::load(&d).is_err());
}

#[test]
fn truncated_dataset_file_is_an_error() {
    let d = tmpdir("truncated");
    let p = d.join("data.ccbin");
    let mut m = BinMat::zeros(10, 100);
    m.set(3, 42, true);
    save_binmat(&p, &m, None).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    std::fs::write(&p, &bytes[..bytes.len() - 9]).unwrap();
    assert!(load_binmat(&p).is_err());
}

#[test]
fn dataset_roundtrip_survives_reload() {
    // positive control for the negative tests above
    let d = tmpdir("ok");
    let p = d.join("data.ccbin");
    let mut m = BinMat::zeros(5, 70);
    m.set(0, 69, true);
    m.set(4, 0, true);
    save_binmat(&p, &m, Some(&[1, 2, 3, 4, 5])).unwrap();
    let (m2, l2) = load_binmat(&p).unwrap();
    assert_eq!(m, m2);
    assert_eq!(l2.unwrap(), vec![1, 2, 3, 4, 5]);
}

#[test]
fn cli_rejects_bad_arguments() {
    use clustercluster::cli::Args;
    assert!(Args::parse(vec!["run".into(), "notaflag".into()]).is_err());
    let a = Args::parse(vec!["run".into(), "--workers".into(), "x".into()]).unwrap();
    assert!(a.get_usize("workers", 1).is_err());
}

#[test]
fn scorer_asserts_on_shape_mismatch() {
    use clustercluster::runtime::{FallbackScorer, Scorer};
    let m = BinMat::zeros(4, 8);
    let mut s = FallbackScorer::new();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // w1 has the wrong length for (d=8, j=3)
        s.predictive_density(&m, &[0.0; 10], &[0.0; 24], &[0.0; 3], 8, 3)
    }));
    assert!(res.is_err(), "shape mismatch must not be silent");
}

#[test]
fn bad_magic_rejected() {
    let d = tmpdir("magic");
    let p = d.join("data.ccbin");
    std::fs::write(&p, b"GARBAGE!________________________").unwrap();
    assert!(load_binmat(Path::new(&p)).is_err());
}
