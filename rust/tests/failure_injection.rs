//! Failure injection: the runtime and IO layers must fail loudly and
//! cleanly on corrupt or missing inputs — no partial loads, no silent
//! wrong numbers. Includes the checkpoint μ-state contract: a resumed
//! non-uniform-μ run must continue from the saved μ vector, and every
//! path that could silently reset μ (legacy format, mode mismatch) must
//! be an error instead.

use clustercluster::coordinator::{Checkpoint, Coordinator, CoordinatorConfig, MuMode};
use clustercluster::data::io::{load_binmat, save_binmat};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::data::BinMat;
use clustercluster::mapreduce::CommModel;
use clustercluster::model::ModelSpec;
use clustercluster::rng::Pcg64;
use clustercluster::runtime::PjrtScorer;
use clustercluster::sampler::{KernelAssignment, KernelKind};
use std::path::{Path, PathBuf};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join("cc_failures").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_an_error() {
    let d = tmpdir("missing");
    let err = PjrtScorer::load(&d).unwrap_err();
    assert!(err.to_string().contains("manifest"), "{err}");
}

#[test]
fn malformed_manifest_line_is_an_error() {
    let d = tmpdir("malformed");
    std::fs::write(d.join("manifest.txt"), "only three fields\n").unwrap();
    let err = PjrtScorer::load(&d).unwrap_err();
    assert!(err.to_string().contains("malformed"), "{err}");
}

#[test]
fn empty_manifest_is_an_error() {
    let d = tmpdir("empty");
    std::fs::write(d.join("manifest.txt"), "# nothing but comments\n\n").unwrap();
    let err = PjrtScorer::load(&d).unwrap_err();
    assert!(err.to_string().contains("no variants"), "{err}");
}

#[test]
fn corrupt_hlo_text_is_an_error() {
    let d = tmpdir("corrupt_hlo");
    std::fs::write(d.join("bad.hlo.txt"), "HloModule this is not valid hlo {{{").unwrap();
    std::fs::write(
        d.join("manifest.txt"),
        "bad loglik 64 256 128 bad.hlo.txt\n",
    )
    .unwrap();
    assert!(PjrtScorer::load(&d).is_err());
}

#[test]
fn manifest_pointing_at_missing_file_is_an_error() {
    let d = tmpdir("dangling");
    std::fs::write(
        d.join("manifest.txt"),
        "ghost loglik 64 256 128 ghost.hlo.txt\n",
    )
    .unwrap();
    assert!(PjrtScorer::load(&d).is_err());
}

#[test]
fn truncated_dataset_file_is_an_error() {
    let d = tmpdir("truncated");
    let p = d.join("data.ccbin");
    let mut m = BinMat::zeros(10, 100);
    m.set(3, 42, true);
    save_binmat(&p, &m, None).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    std::fs::write(&p, &bytes[..bytes.len() - 9]).unwrap();
    assert!(load_binmat(&p).is_err());
}

#[test]
fn dataset_roundtrip_survives_reload() {
    // positive control for the negative tests above
    let d = tmpdir("ok");
    let p = d.join("data.ccbin");
    let mut m = BinMat::zeros(5, 70);
    m.set(0, 69, true);
    m.set(4, 0, true);
    save_binmat(&p, &m, Some(&[1, 2, 3, 4, 5])).unwrap();
    let (m2, l2) = load_binmat(&p).unwrap();
    assert_eq!(m, m2);
    assert_eq!(l2.unwrap(), vec![1, 2, 3, 4, 5]);
}

#[test]
fn cli_rejects_bad_arguments() {
    use clustercluster::cli::Args;
    assert!(Args::parse(vec!["run".into(), "notaflag".into()]).is_err());
    let a = Args::parse(vec!["run".into(), "--workers".into(), "x".into()]).unwrap();
    assert!(a.get_usize("workers", 1).is_err());
}

#[test]
fn scorer_asserts_on_shape_mismatch() {
    use clustercluster::runtime::{FallbackScorer, Scorer};
    let m = BinMat::zeros(4, 8);
    let mut s = FallbackScorer::new();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // w1 has the wrong length for (d=8, j=3)
        s.predictive_density(&m, &[0.0; 10], &[0.0; 24], &[0.0; 3], 8, 3)
    }));
    assert!(res.is_err(), "shape mismatch must not be silent");
}

#[test]
fn bad_magic_rejected() {
    let d = tmpdir("magic");
    let p = d.join("data.ccbin");
    std::fs::write(&p, b"GARBAGE!________________________").unwrap();
    assert!(load_binmat(Path::new(&p)).is_err());
}

fn adaptive_cfg(workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        mu_mode: MuMode::Adaptive {
            target_occupancy: 1.0,
        },
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    }
}

#[test]
fn resumed_adaptive_run_continues_from_saved_mu() {
    // the failure being injected: a restart. The resumed chain must pick
    // up the saved (generally non-uniform) μ bit-for-bit — resuming with
    // a silently re-uniformized μ would be a different chain.
    let ds = SyntheticConfig {
        n: 300,
        d: 12,
        clusters: 3,
        beta: 0.2,
        seed: 41,
    }
    .generate_with_test_fraction(0.0);
    // SizeProportional resamples μ every round, so the captured μ is
    // guaranteed off-uniform; the same save/load/restore path serves
    // Adaptive (exercised below for the mode-mismatch contract)
    let cfg = CoordinatorConfig {
        workers: 3,
        mu_mode: MuMode::SizeProportional,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(42);
    let mut coord = Coordinator::new(&ds.train, cfg.clone(), &mut rng);
    for _ in 0..6 {
        coord.step(&mut rng);
    }
    let saved_mu: Vec<u64> = coord.mu().iter().map(|m| m.to_bits()).collect();
    assert!(
        coord.mu().iter().any(|&m| (m - 1.0 / 3.0).abs() > 1e-12),
        "test needs a non-uniform μ to be meaningful: {:?}",
        coord.mu()
    );
    let d = tmpdir("mu_resume");
    let p = d.join("state.ccckpt");
    coord.save_checkpoint(&p).unwrap();

    let ckpt = Checkpoint::load(&p).unwrap();
    let mut rng2 = Pcg64::seed_from(43);
    let resumed = Coordinator::resume(&ds.train, cfg, &ckpt, &mut rng2).unwrap();
    let resumed_mu: Vec<u64> = resumed.mu().iter().map(|m| m.to_bits()).collect();
    assert_eq!(resumed_mu, saved_mu, "resume reinitialized μ");
}

#[test]
fn mu_mode_mismatch_on_resume_is_an_error() {
    let ds = SyntheticConfig {
        n: 120,
        d: 8,
        clusters: 2,
        beta: 0.3,
        seed: 44,
    }
    .generate_with_test_fraction(0.0);
    let cfg = adaptive_cfg(2);
    let mut rng = Pcg64::seed_from(45);
    let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
    coord.step(&mut rng);
    let ckpt = Checkpoint::capture(&coord);
    // uniform config may not consume an adaptive checkpoint…
    let uniform = CoordinatorConfig {
        workers: 2,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    assert!(Coordinator::resume(&ds.train, uniform, &ckpt, &mut rng).is_err());
    // …and a different adaptive target is a different mode too
    let other_target = CoordinatorConfig {
        mu_mode: MuMode::Adaptive {
            target_occupancy: 2.0,
        },
        ..adaptive_cfg(2)
    };
    assert!(Coordinator::resume(&ds.train, other_target, &ckpt, &mut rng).is_err());
    // the matching config resumes fine (positive control), continuing
    // from the checkpoint's exact μ
    let ok = Coordinator::resume(&ds.train, adaptive_cfg(2), &ckpt, &mut rng).unwrap();
    assert_eq!(
        ok.mu().iter().map(|m| m.to_bits()).collect::<Vec<_>>(),
        ckpt.mu.iter().map(|m| m.to_bits()).collect::<Vec<_>>()
    );
}

#[test]
fn split_merge_kernel_tag_mismatch_on_resume_is_an_error() {
    // the failure being injected: resuming a split–merge-composite run
    // under a different kernel config. The new CCCKPT2 kernel tags must
    // survive the save/load roundtrip and mismatches must be loud —
    // silently continuing with a different transition operator would be
    // a different chain.
    let ds = SyntheticConfig {
        n: 150,
        d: 8,
        clusters: 2,
        beta: 0.3,
        seed: 48,
    }
    .generate_with_test_fraction(0.0);
    let cfg_sm = CoordinatorConfig {
        workers: 2,
        kernel_assignment: KernelAssignment::AllSame(KernelKind::SplitMergeGibbs),
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(49);
    let mut coord = Coordinator::new(&ds.train, cfg_sm.clone(), &mut rng);
    coord.step(&mut rng);
    let d = tmpdir("sm_kernel_tag");
    let p = d.join("state.ccckpt");
    coord.save_checkpoint(&p).unwrap();
    let ckpt = Checkpoint::load(&p).unwrap();
    assert_eq!(ckpt.kernels, vec![KernelKind::SplitMergeGibbs; 2]);

    // plain gibbs may not consume a split–merge checkpoint…
    let cfg_gibbs = CoordinatorConfig {
        kernel_assignment: KernelAssignment::AllSame(KernelKind::CollapsedGibbs),
        ..cfg_sm.clone()
    };
    let e = Coordinator::resume(&ds.train, cfg_gibbs, &ckpt, &mut rng).unwrap_err();
    assert!(e.contains("kernel assignment"), "{e}");
    // …nor may the other composite (the base sweep is part of the tag)
    let cfg_smw = CoordinatorConfig {
        kernel_assignment: KernelAssignment::AllSame(KernelKind::SplitMergeWalker),
        ..cfg_sm.clone()
    };
    let e = Coordinator::resume(&ds.train, cfg_smw, &ckpt, &mut rng).unwrap_err();
    assert!(e.contains("kernel assignment"), "{e}");
    // the matching config resumes and keeps running (positive control)
    let mut ok = Coordinator::resume(&ds.train, cfg_sm, &ckpt, &mut rng).unwrap();
    assert_eq!(
        ok.shard_kernels().to_vec(),
        vec![KernelKind::SplitMergeGibbs; 2]
    );
    ok.step(&mut rng);
    ok.check_invariants().unwrap();
}

#[test]
fn model_tag_mismatch_on_resume_is_an_error() {
    // the failure being injected: resuming a Bernoulli checkpoint under
    // a Gaussian `--model` config. The CCCKPT3 model tag must survive
    // the save/load roundtrip, and a mismatch must be loud from BOTH
    // entry points — silently rebinding the saved assignments to a
    // different likelihood would be a different chain on different math.
    let ds = SyntheticConfig {
        n: 120,
        d: 8,
        clusters: 2,
        beta: 0.3,
        seed: 52,
    }
    .generate_with_test_fraction(0.0);
    let cfg = CoordinatorConfig {
        workers: 2,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(53);
    let mut coord = Coordinator::new(&ds.train, cfg.clone(), &mut rng);
    coord.step(&mut rng);
    let d = tmpdir("model_tag");
    let p = d.join("state.ccckpt");
    coord.save_checkpoint(&p).unwrap();
    let ckpt = Checkpoint::load(&p).unwrap();
    assert_eq!(ckpt.model_tag, ModelSpec::Bernoulli.tag());

    let gauss = CoordinatorConfig {
        model: ModelSpec::DEFAULT_GAUSSIAN,
        ..cfg.clone()
    };
    let e = Coordinator::resume(&ds.train, gauss, &ckpt, &mut rng).unwrap_err();
    assert!(e.contains("model tag"), "{e}");

    // the serial entry point shares the contract (its checkpoints are
    // the 1-shard case of the same format)
    use clustercluster::serial::{SerialConfig, SerialGibbs};
    let scfg = SerialConfig::default();
    let mut srng = Pcg64::seed_from(54);
    let g = SerialGibbs::init_from_prior(&ds.train, scfg, &mut srng);
    let sckpt = g.to_checkpoint();
    let bad = SerialConfig {
        model: ModelSpec::DEFAULT_CATEGORICAL,
        ..scfg
    };
    let e = SerialGibbs::resume(&ds.train, bad, &sckpt, &mut srng).unwrap_err();
    assert!(e.contains("model tag"), "{e}");

    // the matching configs resume and keep running (positive controls)
    let mut ok = Coordinator::resume(&ds.train, cfg, &ckpt, &mut rng).unwrap();
    ok.step(&mut rng);
    ok.check_invariants().unwrap();
    let mut sok = SerialGibbs::resume(&ds.train, scfg, &sckpt, &mut srng).unwrap();
    sok.sweep(&mut srng);
    sok.check_invariants().unwrap();
}

#[test]
fn legacy_v2_checkpoint_loads_as_bernoulli_and_resumes() {
    // back-compat contract: a pre-model-tag CCCKPT2 file must load as
    // model tag 0 (Beta–Bernoulli) with hyper = β and resume cleanly.
    // Built by byte surgery on a real CCCKPT3 file: the v2 layout is the
    // v3 layout minus the model-tag word after α, and that word is 0 for
    // Bernoulli, so the trailing checksum needs no adjustment.
    let ds = SyntheticConfig {
        n: 140,
        d: 8,
        clusters: 2,
        beta: 0.3,
        seed: 56,
    }
    .generate_with_test_fraction(0.0);
    let cfg = CoordinatorConfig {
        workers: 2,
        update_beta: true,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(57);
    let mut coord = Coordinator::new(&ds.train, cfg.clone(), &mut rng);
    for _ in 0..3 {
        coord.step(&mut rng);
    }
    let d = tmpdir("v2_compat");
    let p3 = d.join("v3.ccckpt");
    coord.save_checkpoint(&p3).unwrap();
    let v3 = std::fs::read(&p3).unwrap();
    assert_eq!(&v3[..8], b"CCCKPT3\n");
    assert_eq!(&v3[16..24], &[0u8; 8], "Bernoulli model tag must be 0");
    let mut v2 = Vec::with_capacity(v3.len() - 8);
    v2.extend_from_slice(b"CCCKPT2\n");
    v2.extend_from_slice(&v3[8..16]); // α bits
    v2.extend_from_slice(&v3[24..]); // β length onwards, checksum intact
    let p2 = d.join("v2.ccckpt");
    std::fs::write(&p2, &v2).unwrap();

    let ckpt2 = Checkpoint::load(&p2).unwrap();
    let ckpt3 = Checkpoint::load(&p3).unwrap();
    assert_eq!(ckpt2, ckpt3, "v2 load must equal the v3 original");
    assert_eq!(ckpt2.model_tag, 0);
    assert_eq!(ckpt2.hyper.len(), 8, "v2 hyper vector is the β vector");

    let mut ok = Coordinator::resume(&ds.train, cfg, &ckpt2, &mut rng).unwrap();
    ok.step(&mut rng);
    ok.check_invariants().unwrap();
}

#[test]
fn legacy_v1_checkpoint_is_rejected_not_silently_resumed() {
    // a CCCKPT1 file carries no μ state; loading it must be a loud error
    // (resuming would silently reset μ to uniform)
    let d = tmpdir("v1_ckpt");
    let p = d.join("old.ccckpt");
    let mut bytes = b"CCCKPT1\n".to_vec();
    bytes.extend_from_slice(&[0u8; 64]);
    std::fs::write(&p, &bytes).unwrap();
    let err = Checkpoint::load(&p).unwrap_err();
    assert!(err.to_string().contains("CCCKPT1"), "{err}");
}

#[test]
fn truncated_v2_checkpoint_is_rejected() {
    let ds = SyntheticConfig {
        n: 100,
        d: 8,
        clusters: 2,
        beta: 0.3,
        seed: 46,
    }
    .generate_with_test_fraction(0.0);
    let mut rng = Pcg64::seed_from(47);
    let mut coord = Coordinator::new(&ds.train, adaptive_cfg(2), &mut rng);
    coord.step(&mut rng);
    let d = tmpdir("v2_trunc");
    let p = d.join("state.ccckpt");
    coord.save_checkpoint(&p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    // drop the tail (checksum + part of the last shard)
    std::fs::write(&p, &bytes[..bytes.len() - 24]).unwrap();
    assert!(Checkpoint::load(&p).is_err());
}

/// A small but fully populated CCCKPT3 file (non-trivial α, β vector,
/// μ, kernel tags, and two shards of assignments) for corruption fuzz.
fn small_valid_checkpoint(dir_name: &str) -> (PathBuf, Vec<u8>) {
    let ds = SyntheticConfig {
        n: 24,
        d: 4,
        clusters: 2,
        beta: 0.3,
        seed: 58,
    }
    .generate_with_test_fraction(0.0);
    let mut rng = Pcg64::seed_from(59);
    let mut coord = Coordinator::new(&ds.train, adaptive_cfg(2), &mut rng);
    coord.step(&mut rng);
    let d = tmpdir(dir_name);
    let p = d.join("state.ccckpt");
    coord.save_checkpoint(&p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    assert_eq!(&bytes[..8], b"CCCKPT3\n");
    Checkpoint::load(&p).expect("the uncorrupted file must load");
    (p, bytes)
}

#[test]
fn every_checkpoint_truncation_is_an_error_never_a_panic() {
    // a crash can tear a write at ANY byte boundary; whatever prefix
    // survives, `load` must return Err — it must not panic (a panicking
    // loader would poison auto-resume's newest→oldest generation scan)
    // and must not "succeed" on a partial state
    let (p, bytes) = small_valid_checkpoint("trunc_fuzz");
    for len in 0..bytes.len() {
        std::fs::write(&p, &bytes[..len]).unwrap();
        let res = std::panic::catch_unwind(|| Checkpoint::load(&p));
        let loaded = res.unwrap_or_else(|_| panic!("load PANICKED on {len}-byte prefix"));
        assert!(
            loaded.is_err(),
            "{len}-byte prefix of a {}-byte checkpoint loaded successfully",
            bytes.len()
        );
    }
}

#[test]
fn every_single_bit_flip_in_a_checkpoint_is_an_error_never_a_panic() {
    // one flipped bit anywhere — magic, a length word (which must not
    // drive an unbounded allocation), a payload word, or the checksum
    // trailer itself — must surface as Err from `load`. A flip in a
    // payload word changes the wrapping sum; a flip in the trailer
    // breaks it against the unchanged sum; a flip in the magic fails
    // the version check. Nothing may panic.
    let (p, bytes) = small_valid_checkpoint("bitflip_fuzz");
    for pos in 0..bytes.len() {
        for bit in 0..8u8 {
            let mut corrupt = bytes.clone();
            corrupt[pos] ^= 1 << bit;
            std::fs::write(&p, &corrupt).unwrap();
            let res = std::panic::catch_unwind(|| Checkpoint::load(&p));
            let loaded = res
                .unwrap_or_else(|_| panic!("load PANICKED with bit {bit} of byte {pos} flipped"));
            assert!(
                loaded.is_err(),
                "checkpoint with bit {bit} of byte {pos} flipped loaded successfully"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Worker-pool failure paths (the submit/poll completion channel): a
// panicking map task must propagate to the caller without wedging the
// round, and out-of-order completion must never scramble the
// input-order indexing of results or `map_durations`.
// ---------------------------------------------------------------------

#[test]
fn pool_survives_a_panicking_round_and_stays_usable() {
    use clustercluster::mapreduce::MapReduce;
    let mr = MapReduce::new(3);
    // round 1: one task panics mid-fleet. The completion drain must
    // still account for every job (no deadlock waiting on a completion
    // that never comes) and re-raise the original payload.
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _ = mr.map((0..12u64).collect(), |_, x| {
            if x == 7 {
                panic!("injected shard failure");
            }
            x * 2
        });
    }));
    let payload = caught.expect_err("panic must propagate to the caller");
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .map(str::to_string)
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_default();
    assert!(msg.contains("injected shard failure"), "payload lost: {msg:?}");
    // round 2: the SAME pool must still run clean rounds afterwards —
    // the panic consumed one job, not a worker thread or the channel.
    let (out, durs) = mr.map((0..12u64).collect(), |_, x| x + 1);
    assert_eq!(out, (1..=12).collect::<Vec<u64>>());
    assert_eq!(durs.len(), 12);
}

#[test]
fn out_of_order_completion_keeps_result_and_duration_indexing() {
    use clustercluster::mapreduce::MapReduce;
    use std::time::Duration;
    // tasks finish in roughly REVERSE submission order (earlier index =
    // longer sleep), so completion rank disagrees with input index; the
    // result vector and map_durations must still line up by input index.
    let mr = MapReduce::new(4);
    let n = 8usize;
    let mut completions: Vec<(usize, usize)> = Vec::new();
    let (out, durs) = mr.map_collect(
        (0..n).collect(),
        |i, x: usize| {
            assert_eq!(i, x, "task handed the wrong input");
            std::thread::sleep(Duration::from_millis(((n - 1 - i) * 12) as u64));
            i * 100
        },
        |rank, idx| completions.push((rank, idx)),
    );
    assert_eq!(out, (0..n).map(|i| i * 100).collect::<Vec<_>>());
    assert_eq!(durs.len(), n);
    // durations must belong to their input index: task i slept
    // ~(n-1-i)*12ms, so early indices must show the longer measured
    // compute (generous slack for scheduler noise)
    assert!(
        durs[0] > durs[n - 1],
        "duration indexing scrambled: durs[0]={:?} durs[{}]={:?}",
        durs[0],
        n - 1,
        durs[n - 1]
    );
    // the completion callback saw every task exactly once, ranks in order
    assert_eq!(
        completions.iter().map(|&(r, _)| r).collect::<Vec<_>>(),
        (0..n).collect::<Vec<_>>()
    );
    let mut idxs: Vec<usize> = completions.iter().map(|&(_, i)| i).collect();
    idxs.sort_unstable();
    assert_eq!(idxs, (0..n).collect::<Vec<_>>());
}

// ---------------------------------------------------------------------
// Concurrent-scheduler failure paths: a shard panicking mid-map under
// the streaming round must surface its payload without deadlocking the
// drain or leaking staged state into the next round, and a
// pathologically slow shard must not starve the others' bonus grants.
// ---------------------------------------------------------------------

#[test]
fn concurrent_round_panic_surfaces_and_pool_stays_clean() {
    use clustercluster::mapreduce::MapReduce;
    use std::sync::Arc;
    use std::time::Duration;
    let mut mr = MapReduce::new(3);
    // delay the doomed task so every healthy shard finishes its base
    // sweep AND its follow-up grant before the panic lands — the staged
    // set is then deterministic
    mr.set_delay_hook(Some(Arc::new(|i| {
        Duration::from_millis(if i == 2 { 120 } else { 0 })
    })));
    let mut staged: Vec<usize> = Vec::new();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _ = mr.map_streaming(
            (0..4u64).collect(),
            |i, x| {
                if i == 2 {
                    panic!("shard exploded mid-map");
                }
                x * 10
            },
            |_, r| r + 1,
            |ev| {
                if ev.followups_done == 0 {
                    true // grant one follow-up sweep
                } else {
                    staged.push(ev.index); // stage on final completion
                    false
                }
            },
        );
    }));
    let payload = caught.expect_err("mid-map panic must reach the caller");
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .map(str::to_string)
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_default();
    assert!(msg.contains("shard exploded mid-map"), "payload lost: {msg:?}");
    // the panicking shard must never have staged anything…
    assert!(!staged.contains(&2), "panicking shard leaked staged state");
    // …and the healthy shards all finished their grant and staged once
    staged.sort_unstable();
    assert_eq!(staged, vec![0, 1, 3]);
    // the SAME pool runs a clean streaming round afterwards: the panic
    // consumed one job, not a worker thread or the completion channel
    mr.set_delay_hook(None);
    let mut events = 0usize;
    let (out, _) = mr.map_streaming(
        (0..4u64).collect(),
        |_, x| x * 10 + 1,
        |_, r| r,
        |_| {
            events += 1;
            false
        },
    );
    assert_eq!(out, vec![1, 11, 21, 31]);
    assert_eq!(events, 4);
}

#[test]
fn coordinator_round_panic_does_not_leak_staged_moves() {
    use clustercluster::testing::enumeration_fixture;
    use std::sync::Arc;
    use std::time::Duration;
    let data = enumeration_fixture();
    let cfg = CoordinatorConfig {
        workers: 3,
        comm: CommModel::free(),
        parallelism: 3,
        overlap: true,
        max_bonus_sweeps: 2,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(95);
    let mut coord = Coordinator::new(&data, cfg, &mut rng);
    // a clean round first, so there is prior staged-move state a leaky
    // failure path could corrupt
    coord.step(&mut rng);
    coord.check_invariants().unwrap();
    let moves_before = coord.last_shuffle_moves().to_vec();
    assert!(!moves_before.is_empty(), "fixture round must shuffle clusters");

    // shard 1 crashes mid-map (a panicking delay hook is an injected
    // shard failure: it unwinds inside the worker's task envelope)
    coord.set_map_delay_hook(Some(Arc::new(|i| {
        if i == 1 {
            panic!("shard 1 crashed mid-map");
        }
        Duration::ZERO
    })));
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        coord.step(&mut rng);
    }));
    let payload = caught.expect_err("shard crash must surface, not deadlock");
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .map(str::to_string)
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_default();
    assert!(msg.contains("shard 1 crashed mid-map"), "payload lost: {msg:?}");
    // the aborted round staged nothing: the previous round's decisions
    // are untouched (no half-round moves leaked into coordinator state)
    assert_eq!(coord.last_shuffle_moves(), &moves_before[..]);
    // the poisoned-coordinator contract: the shards were consumed by the
    // aborted round — the coordinator reports empty states rather than
    // pretending a half-swept round is a valid chain state
    assert!(coord.states().is_empty());
}

#[test]
fn slow_shard_does_not_starve_followup_grants() {
    use clustercluster::mapreduce::MapReduce;
    use std::sync::Arc;
    use std::time::Duration;
    // task 0 is pathologically slow; every other task must receive AND
    // complete its follow-up grant while 0 is still running — grants are
    // issued per completion, never gated on the round's stragglers
    let mut mr = MapReduce::new(4);
    mr.set_delay_hook(Some(Arc::new(|i| {
        Duration::from_millis(if i == 0 { 150 } else { 0 })
    })));
    let mut events: Vec<(usize, usize)> = Vec::new();
    let _ = mr.map_streaming(
        (0..4usize).collect(),
        |_, x| x,
        |_, r| r,
        |ev| {
            events.push((ev.index, ev.followups_done));
            ev.followups_done == 0 && ev.index != 0
        },
    );
    let pos = |target: (usize, usize)| {
        events
            .iter()
            .position(|&e| e == target)
            .unwrap_or_else(|| panic!("event {target:?} missing from {events:?}"))
    };
    for i in 1..4 {
        assert!(
            pos((i, 1)) < pos((0, 0)),
            "shard {i}'s grant waited for the slow shard: {events:?}"
        );
    }
}

#[test]
fn slow_shard_leaves_chain_state_and_grants_unchanged() {
    use clustercluster::testing::enumeration_fixture;
    use std::sync::Arc;
    use std::time::Duration;
    // a 2ms-per-round injected straggler must change NOTHING about the
    // chain: same assignments, same α, same bonus grants — the delays
    // only reorder completions, and chain state is completion-order-free
    let data = enumeration_fixture();
    let cfg = |parallelism: usize| CoordinatorConfig {
        workers: 3,
        comm: CommModel::free(),
        parallelism,
        overlap: true,
        max_bonus_sweeps: 2,
        ..Default::default()
    };
    let run = |parallelism: usize, delayed: bool| {
        let mut rng = Pcg64::seed_from(96);
        let mut coord = Coordinator::new(&data, cfg(parallelism), &mut rng);
        if delayed {
            coord.set_map_delay_hook(Some(Arc::new(|i| {
                Duration::from_millis(if i == 0 { 2 } else { 0 })
            })));
        }
        for _ in 0..120 {
            coord.step(&mut rng);
            coord.check_invariants().unwrap();
        }
        let granted: u64 = coord.states().iter().map(|s| s.bonus_sweeps()).sum();
        (coord.assignments(), coord.alpha().to_bits(), granted)
    };
    let reference = run(1, false);
    assert!(
        reference.2 > 0,
        "fixture must exercise the bonus-grant path for the test to bite"
    );
    let injected = run(3, true);
    assert_eq!(reference, injected, "slow shard perturbed the chain");
}
