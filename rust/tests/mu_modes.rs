//! Exactness + invariance gates for the supercluster granularity layer:
//! every [`MuMode`] (uniform, size-proportional, adaptive) and every
//! kernel assignment (including per-shard mixing) must leave the TRUE
//! DPM posterior invariant.
//!
//! The strongest check mirrors `rust/tests/posterior_exactness.rs`: on a
//! 6-point dataset we enumerate all 203 partitions, compute the exact
//! posterior, and require the empirical distribution of the K=3
//! coordinator chain to match in total variation — under each
//! non-uniform μ mode and under a mixed `gibbs,walker` assignment. The
//! μ updates are Gibbs/MH steps on the extended (partition, s, μ) space
//! (DESIGN.md §6), so the partition marginal must be untouched; these
//! gates are the empirical certificate of that argument.

use clustercluster::coordinator::{Coordinator, CoordinatorConfig, MuMode};
use clustercluster::mapreduce::CommModel;
use clustercluster::model::Model;
use clustercluster::rng::Pcg64;
use clustercluster::sampler::{KernelAssignment, KernelKind};
use clustercluster::testing::{
    canonical_partition, enumerate_posterior, enumeration_fixture, partition_tv_distance, ENUM_D,
};
use std::collections::HashMap;

const ALPHA: f64 = 1.3;
const BETA: f64 = 0.6;

/// TV distance of a K=3 coordinator chain under the given granularity
/// mode and kernel assignment against the enumerated posterior.
fn coordinator_tv(mu_mode: MuMode, kernel_assignment: KernelAssignment, seed: u64) -> f64 {
    let data = enumeration_fixture();
    let model = Model::bernoulli(ENUM_D, BETA);
    let truth = enumerate_posterior(&data, &model, ALPHA);

    let cfg = CoordinatorConfig {
        workers: 3,
        local_sweeps: 1,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        shuffle: true,
        mu_mode,
        kernel_assignment,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(seed);
    let mut coord = Coordinator::new(&data, cfg, &mut rng);
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    let burn = 2_000u64;
    let rounds = 60_000u64;
    for it in 0..(burn + rounds) {
        coord.step(&mut rng);
        if it >= burn {
            *counts.entry(canonical_partition(&coord.assignments())).or_default() += 1;
        }
    }
    coord.check_invariants().unwrap();
    // μ must still be a simplex after 62k granularity updates
    let mu_total: f64 = coord.mu().iter().sum();
    assert!((mu_total - 1.0).abs() < 1e-9, "μ drifted off the simplex");
    assert!(coord.mu().iter().all(|&m| m > 0.0 && m.is_finite()));
    partition_tv_distance(&truth, &counts, rounds)
}

fn all_gibbs() -> KernelAssignment {
    KernelAssignment::AllSame(KernelKind::CollapsedGibbs)
}

fn mixed_kernels() -> KernelAssignment {
    KernelAssignment::RoundRobin(vec![KernelKind::CollapsedGibbs, KernelKind::WalkerSlice])
}

#[test]
fn size_proportional_mu_matches_enumerated_posterior() {
    let tv = coordinator_tv(MuMode::SizeProportional, all_gibbs(), 101);
    assert!(tv < 0.05, "SizeProportional K=3 TV distance {tv} too large");
}

#[test]
fn adaptive_mu_matches_enumerated_posterior() {
    let tv = coordinator_tv(
        MuMode::Adaptive {
            target_occupancy: 1.0,
        },
        all_gibbs(),
        102,
    );
    assert!(tv < 0.05, "Adaptive K=3 TV distance {tv} too large");
}

#[test]
fn mixed_kernel_assignment_matches_enumerated_posterior() {
    // gibbs,walker round-robin at K=3: different standard DPM operators
    // on different superclusters within ONE chain stay exact (paper §4)
    let tv = coordinator_tv(MuMode::Uniform, mixed_kernels(), 103);
    assert!(tv < 0.05, "mixed-kernel K=3 TV distance {tv} too large");
}

#[test]
fn adaptive_mu_with_mixed_kernels_matches_enumerated_posterior() {
    // the full stack at once: adaptive granularity + per-shard kernel mixing
    let tv = coordinator_tv(
        MuMode::Adaptive {
            target_occupancy: 1.0,
        },
        mixed_kernels(),
        104,
    );
    assert!(tv < 0.05, "adaptive+mixed K=3 TV distance {tv} too large");
}

#[test]
fn partition_marginal_is_independent_of_mu() {
    // the reparameterization argument behind every mode: for ANY fixed μ
    // the two-stage construction marginalizes to CRP(α) — check E[J]
    // under a strongly non-uniform μ against the CRP expectation
    use clustercluster::supercluster::two_stage_crp_prior;
    let n = 200;
    let alpha = 3.0;
    let want: f64 = (0..n).map(|i| alpha / (alpha + i as f64)).sum();
    let mu = vec![0.7, 0.2, 0.05, 0.05];
    let mut rng = Pcg64::seed_from(7);
    let trials = 3000;
    let mean_j: f64 = (0..trials)
        .map(|_| two_stage_crp_prior(&mut rng, n, alpha, &mu).num_clusters() as f64)
        .sum::<f64>()
        / trials as f64;
    assert!(
        (mean_j - want).abs() < 0.15 * want,
        "non-uniform μ: E[J] {mean_j} vs CRP {want}"
    );
}

#[test]
fn every_mode_keeps_a_larger_chain_valid() {
    // moderate workload, K=4, 30 rounds per mode: data integrity, μ
    // simplex, and (for Uniform) exact 1/K pinning
    use clustercluster::data::synthetic::SyntheticConfig;
    let ds = SyntheticConfig {
        n: 400,
        d: 16,
        clusters: 4,
        beta: 0.1,
        seed: 9,
    }
    .generate_with_test_fraction(0.0);
    for (mode, seed) in [
        (MuMode::Uniform, 201u64),
        (MuMode::SizeProportional, 202),
        (
            MuMode::Adaptive {
                target_occupancy: 1.0,
            },
            203,
        ),
    ] {
        let cfg = CoordinatorConfig {
            workers: 4,
            mu_mode: mode,
            kernel_assignment: mixed_kernels(),
            comm: CommModel::free(),
            parallelism: 1,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(seed);
        let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
        for _ in 0..30 {
            coord.step(&mut rng);
            coord.check_invariants().unwrap();
        }
        let mu = coord.mu();
        assert_eq!(mu.len(), 4);
        assert!((mu.iter().sum::<f64>() - 1.0).abs() < 1e-9, "{mode:?}");
        assert!(mu.iter().all(|&m| m > 0.0), "{mode:?}: {mu:?}");
        assert!(coord.joint_log_prob().is_finite());
        match mode {
            MuMode::Uniform => {
                assert!(
                    mu.iter().all(|&m| (m - 0.25).abs() < 1e-15),
                    "Uniform must pin μ at 1/K: {mu:?}"
                );
                assert_eq!(coord.mu_acceptance_rate(), None);
            }
            MuMode::SizeProportional => {
                assert!(
                    mu.iter().any(|&m| (m - 0.25).abs() > 1e-12),
                    "SizeProportional never moved μ: {mu:?}"
                );
            }
            MuMode::Adaptive { .. } => {
                // one MH proposal per round (acceptance-rate quality is
                // asserted on a long chain in the supercluster unit tests)
                let rate = coord
                    .mu_acceptance_rate()
                    .expect("adaptive mode must attempt MH proposals");
                assert!((0.0..=1.0).contains(&rate));
            }
        }
        // the mixed assignment really is per-shard
        assert_eq!(
            coord.shard_kernels(),
            &[
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
                KernelKind::CollapsedGibbs,
                KernelKind::WalkerSlice,
            ]
        );
        // per-shard observability covers every shard and sums to N
        let stats = coord.shard_stats();
        assert_eq!(stats.len(), 4);
        assert_eq!(stats.iter().map(|s| s.rows).sum::<u64>(), 400);
        for (kk, s) in stats.iter().enumerate() {
            assert_eq!(s.shard, kk);
            assert!((s.mu - mu[kk]).abs() < 1e-15);
        }
    }
}
