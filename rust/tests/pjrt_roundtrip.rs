//! Integration: the PJRT-compiled artifacts produce the same scoring
//! numbers as the pure-Rust fallback (and hence the same numbers the
//! Python L1/L2 tests pinned against the jnp oracle).
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).

use clustercluster::data::BinMat;
use clustercluster::rng::Pcg64;
use clustercluster::runtime::{FallbackScorer, PjrtScorer, Scorer};
use std::path::Path;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::env::var("CC_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let p = Path::new(&dir).to_path_buf();
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts at {}; run `make artifacts`", p.display());
        None
    }
}

/// Load the PJRT scorer, skipping (not failing) when the backend is
/// unavailable — which is always the case in the offline build, where
/// `PjrtScorer` is a validating stub (see rust/src/runtime/pjrt.rs).
fn load_scorer(dir: &Path) -> Option<PjrtScorer> {
    match PjrtScorer::load(dir) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP: PJRT scorer unavailable ({e})");
            None
        }
    }
}

fn rand_problem(
    n: usize,
    d: usize,
    j: usize,
    seed: u64,
) -> (BinMat, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::seed_from(seed);
    let mut m = BinMat::zeros(n, d);
    for r in 0..n {
        for c in 0..d {
            if rng.next_f64() < 0.5 {
                m.set(r, c, true);
            }
        }
    }
    let mut w1 = vec![0.0f32; d * j];
    let mut w0 = vec![0.0f32; d * j];
    for i in 0..d * j {
        let p = 0.05 + 0.9 * rng.next_f64();
        w1[i] = (p as f32).ln();
        w0[i] = (1.0f32 - p as f32).ln();
    }
    let mut logpi = vec![-(j as f32).ln(); j];
    logpi[0] += 0.1; // slightly non-uniform, then renormalize roughly
    (m, w1, w0, logpi)
}

#[test]
fn pjrt_loads_all_manifest_variants() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(s) = load_scorer(&dir) else { return };
    let names = s.variant_names();
    assert!(names.iter().any(|n| n.starts_with("loglik_")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("density_")), "{names:?}");
}

#[test]
fn pjrt_matches_fallback_exact_shape() {
    // problem exactly matching a compiled variant (64, 256, 128)
    let Some(dir) = artifacts_dir() else { return };
    let Some(mut pjrt) = load_scorer(&dir) else { return };
    let mut fall = FallbackScorer::new();
    let (m, w1, w0, logpi) = rand_problem(64, 256, 128, 1);
    let a = pjrt.loglik_matrix(&m, &w1, &w0, 256, 128);
    let b = fall.loglik_matrix(&m, &w1, &w0, 256, 128);
    assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        assert!(
            (a[i] - b[i]).abs() < 2e-3,
            "idx {i}: pjrt {} vs fallback {}",
            a[i],
            b[i]
        );
    }
    let da = pjrt.predictive_density(&m, &w1, &w0, &logpi, 256, 128);
    let db = fall.predictive_density(&m, &w1, &w0, &logpi, 256, 128);
    for i in 0..da.len() {
        assert!((da[i] - db[i]).abs() < 2e-3, "density idx {i}");
    }
}

#[test]
fn pjrt_matches_fallback_with_padding_and_chunking() {
    // odd shape: D smaller than compiled, rows not a multiple of the
    // block, J larger than the largest compiled variant (forces chunking)
    let Some(dir) = artifacts_dir() else { return };
    let Some(mut pjrt) = load_scorer(&dir) else { return };
    let mut fall = FallbackScorer::new();
    let (n, d, j) = (77, 100, 600);
    let (m, w1, w0, logpi) = rand_problem(n, d, j, 2);
    let a = pjrt.loglik_matrix(&m, &w1, &w0, d, j);
    let b = fall.loglik_matrix(&m, &w1, &w0, d, j);
    for i in 0..a.len() {
        assert!(
            (a[i] - b[i]).abs() < 2e-3,
            "idx {i}: pjrt {} vs fallback {}",
            a[i],
            b[i]
        );
    }
    let da = pjrt.predictive_density(&m, &w1, &w0, &logpi, d, j);
    let db = fall.predictive_density(&m, &w1, &w0, &logpi, d, j);
    for i in 0..da.len() {
        assert!((da[i] - db[i]).abs() < 2e-3, "density idx {i}");
    }
    assert!(pjrt.executions > 0, "artifact was actually executed");
}

#[test]
fn pjrt_single_row_and_single_cluster() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(mut pjrt) = load_scorer(&dir) else { return };
    let mut fall = FallbackScorer::new();
    let (m, w1, w0, logpi) = rand_problem(1, 16, 1, 3);
    let a = pjrt.predictive_density(&m, &w1, &w0, &logpi, 16, 1);
    let b = fall.predictive_density(&m, &w1, &w0, &logpi, 16, 1);
    assert_eq!(a.len(), 1);
    assert!((a[0] - b[0]).abs() < 2e-3, "{} vs {}", a[0], b[0]);
}
