//! K=1 reduction: with a single supercluster the coordinator's transition
//! operators collapse to plain Neal-Alg.-3 collapsed Gibbs (μ = [1],
//! local concentration α·1, no shuffle). The two implementations share
//! the posterior but not the RNG stream, so the comparison is
//! distributional: long-run moments of the cluster count and the joint
//! log-probability must agree.

use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::mapreduce::CommModel;
use clustercluster::rng::Pcg64;
use clustercluster::serial::{SerialConfig, SerialGibbs};
use clustercluster::util::mean;

const ALPHA: f64 = 1.5;
const BETA: f64 = 0.4;

fn dataset() -> clustercluster::data::Dataset {
    SyntheticConfig {
        n: 120,
        d: 12,
        clusters: 3,
        beta: 0.15,
        seed: 10,
    }
    .generate_with_test_fraction(0.0)
}

#[test]
fn k1_coordinator_matches_serial_moments() {
    let ds = dataset();

    // serial chain
    let scfg = SerialConfig {
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(1);
    let mut serial = SerialGibbs::init_from_prior(&ds.train, scfg, &mut rng);
    let mut sj = Vec::new();
    let mut slp = Vec::new();
    for it in 0..6_000 {
        serial.sweep(&mut rng);
        if it >= 1_000 {
            sj.push(serial.num_clusters() as f64);
            slp.push(serial.joint_log_prob());
        }
    }

    // K=1 coordinator
    let ccfg = CoordinatorConfig {
        workers: 1,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng2 = Pcg64::seed_from(2);
    let mut coord = Coordinator::new(&ds.train, ccfg, &mut rng2);
    let mut cj = Vec::new();
    let mut clp = Vec::new();
    for it in 0..6_000 {
        coord.step(&mut rng2);
        if it >= 1_000 {
            cj.push(coord.num_clusters() as f64);
            clp.push(coord.joint_log_prob());
        }
    }

    let (mj_s, mj_c) = (mean(&sj), mean(&cj));
    let (mlp_s, mlp_c) = (mean(&slp), mean(&clp));
    assert!(
        (mj_s - mj_c).abs() < 0.25,
        "mean #clusters: serial {mj_s} vs K=1 coordinator {mj_c}"
    );
    assert!(
        (mlp_s - mlp_c).abs() < 0.02 * mlp_s.abs(),
        "mean joint logp: serial {mlp_s} vs K=1 coordinator {mlp_c}"
    );
}

#[test]
fn k1_has_no_shuffle_bytes() {
    let ds = dataset();
    let ccfg = CoordinatorConfig {
        workers: 1,
        comm: CommModel::free(),
        update_beta: false,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(3);
    let mut coord = Coordinator::new(&ds.train, ccfg, &mut rng);
    let rs = coord.step(&mut rng);
    // only the J_k integer is communicated per round at K=1
    assert_eq!(rs.bytes_transferred, 8, "bytes = {}", rs.bytes_transferred);
}
