//! K=1 reduction: with a single supercluster the coordinator's transition
//! operators collapse to the plain serial chain (μ = [1], local
//! concentration α·1, no shuffle).
//!
//! Since the unified-sampler refactor this is **structural**: both entry
//! points run the same `TransitionKernel` over the same `Shard` type,
//! with the kernel on a private stream split identically from the master
//! seed and hyper updates on the master stream. The first suite
//! therefore asserts the two chains are *identical sweep-by-sweep* for
//! every kernel. The older distributional check (independent seeds →
//! matching long-run moments) is kept as a guard against accidental
//! coupling-by-construction bugs.

use clustercluster::coordinator::{Coordinator, CoordinatorConfig, LocalKernel, MuMode};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::mapreduce::CommModel;
use clustercluster::rng::Pcg64;
use clustercluster::sampler::KernelKind;
use clustercluster::serial::{SerialConfig, SerialGibbs};
use clustercluster::testing::canonical_partition as canonical;
use clustercluster::util::mean;

const ALPHA: f64 = 1.5;
const BETA: f64 = 0.4;

fn dataset() -> clustercluster::data::Dataset {
    SyntheticConfig {
        n: 120,
        d: 12,
        clusters: 3,
        beta: 0.15,
        seed: 10,
    }
    .generate_with_test_fraction(0.0)
}

/// The structural claim: same master seed ⇒ the serial sampler and the
/// K=1 coordinator visit the same partition and the same α at every
/// sweep, because they run the same kernel on the same shard abstraction
/// with identically-derived streams. This must hold under EVERY
/// [`MuMode`]: at K=1 μ is degenerate at [1], so the non-uniform modes
/// must consume no master-stream randomness at all (otherwise α would
/// desynchronize from the serial chain).
fn assert_chains_identical(kernel: KernelKind) {
    assert_chains_identical_cfg(kernel, MuMode::Uniform, false);
}

fn assert_chains_identical_mu(kernel: KernelKind, mu_mode: MuMode) {
    assert_chains_identical_cfg(kernel, mu_mode, false);
}

fn assert_chains_identical_cfg(kernel: KernelKind, mu_mode: MuMode, overlap: bool) {
    let ds = dataset();
    let seed = 2024;

    let scfg = SerialConfig {
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: true,
        update_beta: false,
        kernel,
        ..Default::default()
    };
    let mut srng = Pcg64::seed_from(seed);
    let mut serial = SerialGibbs::init_from_prior(&ds.train, scfg, &mut srng);

    let ccfg = CoordinatorConfig {
        workers: 1,
        local_sweeps: 1,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: true,
        update_beta: false,
        mu_mode,
        kernel_assignment: clustercluster::sampler::KernelAssignment::AllSame(kernel),
        comm: CommModel::free(),
        parallelism: 1,
        overlap,
        // a nonzero cap must still grant 0 bonus sweeps at K=1 (the
        // single shard IS the critical path), keeping bit-equivalence
        max_bonus_sweeps: 3,
        ..Default::default()
    };
    let mut crng = Pcg64::seed_from(seed);
    let mut coord = Coordinator::new(&ds.train, ccfg, &mut crng);

    assert_eq!(
        canonical(serial.assignments()),
        canonical(&coord.assignments()),
        "CRP-prior initializations diverged"
    );
    for it in 0..150 {
        serial.sweep(&mut srng);
        coord.step(&mut crng);
        assert_eq!(
            canonical(serial.assignments()),
            canonical(&coord.assignments()),
            "partitions diverged at sweep {it} ({kernel:?})"
        );
        assert_eq!(
            serial.alpha().to_bits(),
            coord.alpha().to_bits(),
            "α diverged at sweep {it}: serial {} vs coordinator {} ({kernel:?})",
            serial.alpha(),
            coord.alpha()
        );
    }
    serial.check_invariants().unwrap();
    coord.check_invariants().unwrap();
}

#[test]
fn k1_chain_identical_collapsed_gibbs() {
    assert_chains_identical(KernelKind::CollapsedGibbs);
}

#[test]
fn k1_chain_identical_walker_slice() {
    assert_chains_identical(KernelKind::WalkerSlice);
}

#[test]
fn k1_chain_identical_split_merge_gibbs() {
    // the composite's MH moves draw from the shard's private stream like
    // any other kernel, so K=1 ≡ serial stays chain-exact
    assert_chains_identical(KernelKind::SplitMergeGibbs);
}

#[test]
fn k1_chain_identical_split_merge_walker() {
    assert_chains_identical(KernelKind::SplitMergeWalker);
}

#[test]
fn k1_chain_identical_with_overlap_on() {
    // at K=1 the overlapped schedule degenerates to the serial chain
    // exactly: no shuffle, no μ update, zero bonus-sweep grants
    // (plan_bonus_sweeps gives the heaviest shard 0), so the master
    // stream is consumed identically and the chains stay bit-equal
    assert_chains_identical_cfg(KernelKind::CollapsedGibbs, MuMode::Uniform, true);
    assert_chains_identical_cfg(KernelKind::WalkerSlice, MuMode::Uniform, true);
}

#[test]
fn k1_chain_identical_size_proportional_mu() {
    // K=1 SizeProportional must be bit-identical to the serial chain:
    // the degenerate μ=[1] Gibbs update is skipped, so the master stream
    // is consumed exactly as serially
    assert_chains_identical_mu(KernelKind::CollapsedGibbs, MuMode::SizeProportional);
    assert_chains_identical_mu(KernelKind::WalkerSlice, MuMode::SizeProportional);
}

#[test]
fn k1_chain_identical_adaptive_mu() {
    assert_chains_identical_mu(
        KernelKind::CollapsedGibbs,
        MuMode::Adaptive {
            target_occupancy: 1.0,
        },
    );
    assert_chains_identical_mu(
        KernelKind::WalkerSlice,
        MuMode::Adaptive {
            target_occupancy: 1.0,
        },
    );
}

#[test]
fn k1_coordinator_matches_serial_moments() {
    let ds = dataset();

    // serial chain
    let scfg = SerialConfig {
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(1);
    let mut serial = SerialGibbs::init_from_prior(&ds.train, scfg, &mut rng);
    let mut sj = Vec::new();
    let mut slp = Vec::new();
    for it in 0..6_000 {
        serial.sweep(&mut rng);
        if it >= 1_000 {
            sj.push(serial.num_clusters() as f64);
            slp.push(serial.joint_log_prob());
        }
    }

    // K=1 coordinator
    let ccfg = CoordinatorConfig {
        workers: 1,
        init_alpha: ALPHA,
        init_beta: BETA,
        update_alpha: false,
        update_beta: false,
        comm: CommModel::free(),
        parallelism: 1,
        ..Default::default()
    };
    let mut rng2 = Pcg64::seed_from(2);
    let mut coord = Coordinator::new(&ds.train, ccfg, &mut rng2);
    let mut cj = Vec::new();
    let mut clp = Vec::new();
    for it in 0..6_000 {
        coord.step(&mut rng2);
        if it >= 1_000 {
            cj.push(coord.num_clusters() as f64);
            clp.push(coord.joint_log_prob());
        }
    }

    let (mj_s, mj_c) = (mean(&sj), mean(&cj));
    let (mlp_s, mlp_c) = (mean(&slp), mean(&clp));
    assert!(
        (mj_s - mj_c).abs() < 0.25,
        "mean #clusters: serial {mj_s} vs K=1 coordinator {mj_c}"
    );
    assert!(
        (mlp_s - mlp_c).abs() < 0.02 * mlp_s.abs(),
        "mean joint logp: serial {mlp_s} vs K=1 coordinator {mlp_c}"
    );
}

#[test]
fn k1_has_no_shuffle_bytes() {
    let ds = dataset();
    let ccfg = CoordinatorConfig {
        workers: 1,
        comm: CommModel::free(),
        update_beta: false,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(3);
    let mut coord = Coordinator::new(&ds.train, ccfg, &mut rng);
    let rs = coord.step(&mut rng);
    // only the J_k integer is communicated per round at K=1
    assert_eq!(rs.bytes_transferred, 8, "bytes = {}", rs.bytes_transferred);
}

#[test]
fn local_kernel_alias_is_the_sampler_kernel_kind() {
    // coordinator::LocalKernel must stay a re-export of sampler::KernelKind
    // so CLI code and tests can use either name for the same selector
    let a: LocalKernel = KernelKind::WalkerSlice;
    assert_eq!(a, LocalKernel::WalkerSlice);
    assert_eq!(LocalKernel::parse("gibbs").unwrap(), KernelKind::CollapsedGibbs);
}
