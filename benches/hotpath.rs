//! Hot-path microbenchmarks (supporting the §Perf pass):
//!
//! * batched scoring throughput — PJRT artifact vs pure-Rust fallback on
//!   the compiled (256, 256, 512) shape;
//! * per-datum Gibbs scan throughput (rows/s), with the cached-table vs
//!   uncached-scoring ablation (DESIGN.md §9);
//! * full-sweep dispatch comparison: scalar candidate scoring vs the
//!   batched `Scorer::score_rows_against_clusters` path (the acceptance
//!   gate: batched must not be slower on the synthetic workload);
//! * coordinator phase split (map / reduce / shuffle shares).

use clustercluster::bench::{bench, FigureEmitter};
use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::data::BinMat;
use clustercluster::mapreduce::CommModel;
use clustercluster::model::{BetaBernoulli, ClusterStats};
use clustercluster::rng::Pcg64;
use clustercluster::runtime::{FallbackScorer, PjrtScorer, Scorer, ScorerKind};
use clustercluster::sampler::{KernelKind, ScoreMode, Shard};
use std::path::Path;

fn rand_problem(n: usize, d: usize, j: usize, seed: u64) -> (BinMat, Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::seed_from(seed);
    let mut m = BinMat::zeros(n, d);
    for r in 0..n {
        for c in 0..d {
            if rng.next_f64() < 0.5 {
                m.set(r, c, true);
            }
        }
    }
    let mut w1 = vec![0.0f32; d * j];
    let mut w0 = vec![0.0f32; d * j];
    for i in 0..d * j {
        let p = 0.05 + 0.9 * rng.next_f64();
        w1[i] = (p as f32).ln();
        w0[i] = (1.0f32 - p as f32).ln();
    }
    (m, w1, w0)
}

fn main() {
    let mut fig = FigureEmitter::new("hotpath");

    // --- batched scoring: artifact vs fallback ---
    let (n, d, j) = (256usize, 256usize, 512usize);
    let (m, w1, w0) = rand_problem(n, d, j, 1);
    let cells = (n * j) as f64;
    let mut fall = FallbackScorer::new();
    let rf = bench("fallback loglik 256x256x512", 1, 10, || {
        std::hint::black_box(fall.loglik_matrix(&m, &w1, &w0, d, j));
    });
    fig.row(&[
        ("fallback_cells_per_s", cells / rf.mean_s),
        ("fallback_mean_s", rf.mean_s),
    ]);
    let dir = std::env::var("CC_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if let Ok(mut pjrt) = PjrtScorer::load(Path::new(&dir)) {
        let rp = bench("pjrt     loglik 256x256x512", 1, 10, || {
            std::hint::black_box(pjrt.loglik_matrix(&m, &w1, &w0, d, j));
        });
        fig.row(&[
            ("pjrt_cells_per_s", cells / rp.mean_s),
            ("pjrt_mean_s", rp.mean_s),
            ("pjrt_vs_fallback", rf.mean_s / rp.mean_s),
        ]);
    } else {
        fig.note("artifacts missing: run `make artifacts` for the PJRT row");
    }

    // --- per-datum scoring: cached table vs uncached ---
    let ds = SyntheticConfig {
        n: 2_000,
        d: 64,
        clusters: 16,
        beta: 0.1,
        seed: 2,
    }
    .generate_with_test_fraction(0.0);
    let model = BetaBernoulli::symmetric(64, 0.5);
    let mut clusters: Vec<ClusterStats> = (0..16).map(|_| ClusterStats::empty(64)).collect();
    for r in 0..ds.train.rows() {
        clusters[r % 16].add(&ds.train, r);
    }
    let rows = ds.train.rows() as f64;
    let rc = bench("scan cached  2000x16 clusters", 1, 20, || {
        let mut acc = 0.0;
        for r in 0..ds.train.rows() {
            for c in clusters.iter_mut() {
                acc += c.score(&model, &ds.train, r);
            }
        }
        std::hint::black_box(acc);
    });
    let ru = bench("scan uncached 2000x16 clusters", 1, 5, || {
        let mut acc = 0.0;
        for r in 0..ds.train.rows() {
            for c in clusters.iter() {
                acc += c.score_uncached(&model, &ds.train, r);
            }
        }
        std::hint::black_box(acc);
    });
    fig.row(&[
        ("cached_rows_per_s", rows / rc.mean_s),
        ("uncached_rows_per_s", rows / ru.mean_s),
        ("cache_speedup", ru.mean_s / rc.mean_s),
    ]);

    // --- full-sweep dispatch: scalar vs batched candidate scoring ---
    let ds3 = SyntheticConfig {
        n: 2_000,
        d: 64,
        clusters: 16,
        beta: 0.1,
        seed: 4,
    }
    .generate_with_test_fraction(0.0);
    let mut model3 = BetaBernoulli::symmetric(64, 0.5);
    model3.build_lut(ds3.train.rows() + 1);
    let make_shard = |mode: ScoreMode| {
        let rows: Vec<usize> = (0..ds3.train.rows()).collect();
        let mut sh = Shard::init_from_prior(&ds3.train, rows, 8.0, Pcg64::seed_from(9));
        sh.set_score_mode(mode);
        sh
    };
    let rows3 = ds3.train.rows() as f64;
    for kind in [KernelKind::CollapsedGibbs, KernelKind::WalkerSlice] {
        let kernel = kind.kernel();
        let mut scalar_sh = make_shard(ScoreMode::Scalar);
        let r_scalar = bench(&format!("sweep scalar  2000x64 {}", kernel.name()), 2, 10, || {
            kernel.sweep(&mut scalar_sh, &ds3.train, &model3);
        });
        let mut batched_sh = make_shard(ScoreMode::Batched(ScorerKind::Fallback));
        let r_batched = bench(&format!("sweep batched 2000x64 {}", kernel.name()), 2, 10, || {
            kernel.sweep(&mut batched_sh, &ds3.train, &model3);
        });
        fig.row(&[
            ("sweep_scalar_rows_per_s", rows3 / r_scalar.mean_s),
            ("sweep_batched_rows_per_s", rows3 / r_batched.mean_s),
            ("batched_vs_scalar", r_scalar.mean_s / r_batched.mean_s),
        ]);
    }

    // --- full coordinator round phase split ---
    let ds2 = SyntheticConfig {
        n: 10_000,
        d: 64,
        clusters: 64,
        beta: 0.05,
        seed: 3,
    }
    .generate_with_test_fraction(0.0);
    let cfg = CoordinatorConfig {
        workers: 8,
        comm: CommModel::free(),
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(3);
    let mut coord = Coordinator::new(&ds2.train, cfg, &mut rng);
    let rr = bench("coordinator round 10000x64", 2, 10, || {
        coord.step(&mut rng);
    });
    let prof = coord.timer.render();
    println!("{prof}");
    let total = coord.timer.total("map")
        + coord.timer.total("reduce")
        + coord.timer.total("shuffle");
    fig.row(&[
        ("round_mean_s", rr.mean_s),
        ("rows_per_s", 10_000.0 / rr.mean_s),
        (
            "map_share",
            coord.timer.total("map").as_secs_f64() / total.as_secs_f64().max(1e-12),
        ),
    ]);
    fig.finish();
}
