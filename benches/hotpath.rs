//! Hot-path microbenchmarks (supporting the §Perf pass):
//!
//! * **perf-baseline matrix** — sweep throughput (rows/s) per
//!   kernel (collapsed Gibbs | Walker | the split–merge:gibbs
//!   composite) × cluster count × bit density × scoring mode
//!   (scalar reference | batched incremental | batched eager ≙ the
//!   pre-incremental engine), written to
//!   `bench_results/BENCH_hotpath.json` (and, with `--update-baseline`,
//!   to the committed repo-root `BENCH_hotpath.json` that CI's
//!   regression gate compares against). `--smoke` runs the same matrix
//!   at CI scale.
//! * batched scoring throughput — PJRT artifact vs pure-Rust fallback on
//!   the compiled (256, 256, 512) shape;
//! * per-datum Gibbs scan throughput (rows/s), with the cached-table vs
//!   uncached-scoring ablation (DESIGN.md §10);
//! * coordinator rounds on the `--overlap off|on` axis: phase split
//!   (map / reduce / shuffle shares), modeled bulk vs overlapped
//!   wall-clock, and the per-shard idle / barrier-wait / bonus-sweep
//!   totals, recorded into the baseline JSON so the overlap speedup is
//!   a measured artifact rather than an asserted one.

use clustercluster::bench::{
    bench, is_smoke, update_baseline, BaselineCase, BaselineEmitter, FigureEmitter,
};
use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::synthetic::{
    SyntheticCategoricalConfig, SyntheticConfig, SyntheticGaussianConfig,
};
use clustercluster::data::{BinMat, DataRef};
use clustercluster::mapreduce::CommModel;
use clustercluster::model::{ClusterStats, Model, ModelSpec};
use clustercluster::rng::Pcg64;
use clustercluster::runtime::{FallbackScorer, PjrtScorer, Scorer, ScorerKind};
use clustercluster::sampler::{KernelKind, ScoreMode, Shard};
use std::path::Path;

fn rand_problem(n: usize, d: usize, j: usize, seed: u64) -> (BinMat, Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::seed_from(seed);
    let mut m = BinMat::zeros(n, d);
    for r in 0..n {
        for c in 0..d {
            if rng.next_f64() < 0.5 {
                m.set(r, c, true);
            }
        }
    }
    let mut w1 = vec![0.0f32; d * j];
    let mut w0 = vec![0.0f32; d * j];
    for i in 0..d * j {
        let p = 0.05 + 0.9 * rng.next_f64();
        w1[i] = (p as f32).ln();
        w0[i] = (1.0f32 - p as f32).ln();
    }
    (m, w1, w0)
}

/// Planted-prototype binary data with a controlled bit density: each of
/// `clusters` prototypes draws every dim 1 w.p. `density`; a row copies
/// its prototype bit w.p. 0.9 and redraws Bernoulli(density) otherwise,
/// so the overall density stays ≈ `density` at any separation.
fn density_data(n: usize, d: usize, clusters: usize, density: f64, seed: u64) -> BinMat {
    let mut rng = Pcg64::seed_from(seed);
    let mut proto = vec![false; clusters * d];
    for b in proto.iter_mut() {
        *b = rng.next_f64() < density;
    }
    let mut m = BinMat::zeros(n, d);
    for r in 0..n {
        let k = r % clusters;
        for c in 0..d {
            let bit = if rng.next_f64() < 0.9 {
                proto[k * d + c]
            } else {
                rng.next_f64() < density
            };
            if bit {
                m.set(r, c, true);
            }
        }
    }
    m
}

/// A shard planted at exactly `clusters` clusters (round-robin), so the
/// measured sweeps run at a controlled J.
fn planted_shard(data: DataRef<'_>, clusters: usize, mode: ScoreMode, eager: bool) -> Shard {
    let rows: Vec<usize> = (0..data.rows()).collect();
    let assign: Vec<u32> = (0..data.rows()).map(|r| (r % clusters) as u32).collect();
    let mut sh = Shard::from_parts(data, rows, assign, Pcg64::seed_from(0xbead)).unwrap();
    sh.set_theta(4.0);
    sh.set_score_mode(mode);
    sh.set_eager_repack(eager);
    sh
}

fn main() {
    let smoke = is_smoke();
    let mut fig = FigureEmitter::new("hotpath");

    // --- perf-baseline matrix: kernel × J × density × scoring mode ---
    let scale = if smoke { "smoke" } else { "full" };
    let smoke_flag = if smoke { "--smoke " } else { "" };
    let provenance = format!(
        "measured ({scale} scale); refresh with: cargo bench --bench hotpath -- \
         {smoke_flag}--update-baseline"
    );
    let mut base = BaselineEmitter::new("hotpath_baseline", &provenance);
    let (bn, bd) = if smoke { (600usize, 64usize) } else { (2_000usize, 128usize) };
    let (warmup, iters) = if smoke { (1, 3) } else { (2, 8) };
    let mut model_b = Model::bernoulli(bd, 0.5);
    model_b.build_lut(bn + 1);
    let modes: [(&str, ScoreMode, bool); 3] = [
        ("scalar", ScoreMode::Scalar, false),
        ("batched", ScoreMode::Batched(ScorerKind::Fallback), false),
        // the pre-incremental engine: held-out column re-packed per datum
        ("batched-eager", ScoreMode::Batched(ScorerKind::Fallback), true),
    ];
    // the split–merge composite rides in the same matrix: its restricted
    // scans share the packed-table scoring path, so the baseline (and
    // the CI regression gate) covers the global-move layer too
    for kind in [
        KernelKind::CollapsedGibbs,
        KernelKind::WalkerSlice,
        KernelKind::SplitMergeGibbs,
    ] {
        let kernel = kind.kernel();
        for &clusters in &[8usize, 48] {
            for &density in &[0.05f64, 0.5] {
                let data = density_data(bn, bd, clusters, density, 0xd5eed);
                for (mode_name, mode, eager) in modes.iter() {
                    let mut sh = planted_shard((&data).into(), clusters, *mode, *eager);
                    let r = bench(
                        &format!(
                            "sweep {} J={clusters} p={density:.2} {mode_name}",
                            kernel.name()
                        ),
                        warmup,
                        iters,
                        || {
                            kernel.sweep(&mut sh, (&data).into(), &model_b);
                        },
                    );
                    base.case(BaselineCase {
                        kernel: kernel.name().to_string(),
                        clusters,
                        density,
                        mode: mode_name.to_string(),
                        rows_per_s: bn as f64 / r.mean_s,
                    });
                }
                // headline ratios: the incremental engine vs the
                // pre-incremental eager repack, and vs scalar
                let key = |mode: &str| {
                    format!("{}|J{clusters}|p{density:.2}|{mode}", kernel.name())
                };
                if let (Some(b), Some(e), Some(s)) = (
                    base.rows_per_s(&key("batched")),
                    base.rows_per_s(&key("batched-eager")),
                    base.rows_per_s(&key("scalar")),
                ) {
                    base.derived(
                        &format!("{}_J{clusters}_p{density:.2}_batched_vs_eager", kernel.name()),
                        b / e,
                    );
                    base.derived(
                        &format!("{}_J{clusters}_p{density:.2}_batched_vs_scalar", kernel.name()),
                        b / s,
                    );
                }
            }
        }
    }
    // --- likelihood model axis: sweep throughput per ComponentModel ---
    //
    // Collapsed-Gibbs sweeps at a planted J under each likelihood, scalar
    // vs batched. Figure rows only — the committed baseline's regression
    // keys stay the Bernoulli matrix above.
    {
        let (mn, md, mj) = if smoke {
            (600usize, 32usize, 16usize)
        } else {
            (2_000usize, 64usize, 16usize)
        };
        let gauss = SyntheticGaussianConfig {
            n: mn,
            d: md,
            clusters: mj,
            spread: 3.0,
            seed: 0x9a55,
        }
        .generate()
        .0;
        let cat = SyntheticCategoricalConfig {
            n: mn,
            d: md,
            card: 6,
            clusters: mj,
            gamma: 0.5,
            seed: 0xca7e,
        }
        .generate()
        .0;
        let axis: [(&str, DataRef<'_>, ModelSpec); 2] = [
            ("gaussian", (&gauss).into(), ModelSpec::DEFAULT_GAUSSIAN),
            ("categorical", (&cat).into(), ModelSpec::DEFAULT_CATEGORICAL),
        ];
        let kernel = KernelKind::CollapsedGibbs.kernel();
        for (model_name, mdata, spec) in axis {
            let mut model = spec.build(mdata, 0.5).unwrap();
            model.build_lut(mn + 1);
            for (mode_name, mode) in [
                ("scalar", ScoreMode::Scalar),
                ("batched", ScoreMode::Batched(ScorerKind::Fallback)),
            ] {
                let mut sh = planted_shard(mdata, mj, mode, false);
                let r = bench(
                    &format!("sweep gibbs {model_name} J={mj} {mode_name}"),
                    warmup,
                    iters,
                    || {
                        kernel.sweep(&mut sh, mdata, &model);
                    },
                );
                fig.row(&[(
                    format!("{model_name}_sweep_{mode_name}_rows_per_s").as_str(),
                    mn as f64 / r.mean_s,
                )]);
            }
        }
    }

    // --- batched scoring: artifact vs fallback ---
    let (n, d, j) = if smoke {
        (64usize, 64usize, 128usize)
    } else {
        (256usize, 256usize, 512usize)
    };
    let (m, w1, w0) = rand_problem(n, d, j, 1);
    let cells = (n * j) as f64;
    let mut fall = FallbackScorer::new();
    let rf = bench("fallback loglik batched shape", 1, 10, || {
        std::hint::black_box(fall.loglik_matrix(&m, &w1, &w0, d, j));
    });
    fig.row(&[
        ("fallback_cells_per_s", cells / rf.mean_s),
        ("fallback_mean_s", rf.mean_s),
    ]);
    let dir = std::env::var("CC_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if let Ok(mut pjrt) = PjrtScorer::load(Path::new(&dir)) {
        let rp = bench("pjrt     loglik batched shape", 1, 10, || {
            std::hint::black_box(pjrt.loglik_matrix(&m, &w1, &w0, d, j));
        });
        fig.row(&[
            ("pjrt_cells_per_s", cells / rp.mean_s),
            ("pjrt_mean_s", rp.mean_s),
            ("pjrt_vs_fallback", rf.mean_s / rp.mean_s),
        ]);
    } else {
        fig.note("artifacts missing: run `make artifacts` for the PJRT row");
    }

    // --- per-datum scoring: cached table vs uncached ---
    let ds = SyntheticConfig {
        n: if smoke { 500 } else { 2_000 },
        d: 64,
        clusters: 16,
        beta: 0.1,
        seed: 2,
    }
    .generate_with_test_fraction(0.0);
    let model = Model::bernoulli(64, 0.5);
    let mut clusters: Vec<ClusterStats> = (0..16).map(|_| ClusterStats::empty(64)).collect();
    for r in 0..ds.train.rows() {
        clusters[r % 16].add(&ds.train, r);
    }
    let rows = ds.train.rows() as f64;
    let rc = bench("scan cached   16 clusters", 1, 20, || {
        let mut acc = 0.0;
        for r in 0..ds.train.rows() {
            for c in clusters.iter_mut() {
                acc += c.score(&model, &ds.train, r);
            }
        }
        std::hint::black_box(acc);
    });
    let ru = bench("scan uncached 16 clusters", 1, 5, || {
        let mut acc = 0.0;
        for r in 0..ds.train.rows() {
            for c in clusters.iter() {
                acc += c.score_uncached(&model, &ds.train, r);
            }
        }
        std::hint::black_box(acc);
    });
    fig.row(&[
        ("cached_rows_per_s", rows / rc.mean_s),
        ("uncached_rows_per_s", rows / ru.mean_s),
        ("cache_speedup", ru.mean_s / rc.mean_s),
    ]);

    // --- coordinator rounds, overlap off|on axis (skipped under --smoke) ---
    //
    // The same 10 000×64 workers-8 problem runs once per round schedule.
    // Per mode the row records the measured host round time, the modeled
    // bulk and overlapped wall-clock of a representative round, and the
    // per-shard idle / barrier-wait / bonus-sweep totals; the derived
    // speedup ratios land in the baseline JSON so the overlap claim is
    // recorded, not asserted.
    if !smoke {
        let ds2 = SyntheticConfig {
            n: 10_000,
            d: 64,
            clusters: 64,
            beta: 0.05,
            seed: 3,
        }
        .generate_with_test_fraction(0.0);
        let mut measured = [0.0f64; 2];
        let mut modeled_bulk = 0.0f64;
        let mut modeled_overlapped = 0.0f64;
        let mut measured_serialized = 0.0f64;
        let mut measured_overlapped = 0.0f64;
        for (mi, (mode_name, overlap)) in
            [("bulk", false), ("overlapped", true)].iter().enumerate()
        {
            let cfg = CoordinatorConfig {
                workers: 8,
                comm: CommModel::free(),
                overlap: *overlap,
                // real host threads: the overlapped row measures the
                // genuinely concurrent scheduler, not an inline replay
                parallelism: 0,
                ..Default::default()
            };
            let mut rng = Pcg64::seed_from(3);
            let mut coord = Coordinator::new(&ds2.train, cfg, &mut rng);
            let rr = bench(
                &format!("coordinator round 10000x64 {mode_name}"),
                2,
                10,
                || {
                    coord.step(&mut rng);
                },
            );
            measured[mi] = rr.mean_s;
            // one representative post-warm round for the modeled figures
            // and the per-shard observability columns
            let rs = coord.step(&mut rng);
            let idle: f64 = coord.shard_stats().iter().map(|s| s.idle_s).sum();
            let barrier: f64 =
                coord.shard_stats().iter().map(|s| s.barrier_wait_s).sum();
            let bonus: u64 =
                coord.shard_stats().iter().map(|s| s.bonus_sweeps).sum();
            let prof = coord.timer.render();
            println!("{prof}");
            let total = coord.timer.total("map")
                + coord.timer.total("reduce")
                + coord.timer.total("shuffle");
            let keys: Vec<String> = [
                "round_mean_s",
                "rows_per_s",
                "map_share",
                "modeled_bulk_s",
                "modeled_overlapped_s",
                "idle_s",
                "barrier_wait_s",
                "bonus_sweeps",
            ]
            .iter()
            .map(|k| format!("{mode_name}_{k}"))
            .collect();
            fig.row(&[
                (keys[0].as_str(), rr.mean_s),
                (keys[1].as_str(), 10_000.0 / rr.mean_s),
                (
                    keys[2].as_str(),
                    coord.timer.total("map").as_secs_f64()
                        / total.as_secs_f64().max(1e-12),
                ),
                (keys[3].as_str(), rs.modeled_bulk_s),
                (keys[4].as_str(), rs.modeled_overlapped_s),
                (keys[5].as_str(), idle),
                (keys[6].as_str(), barrier),
                (keys[7].as_str(), bonus as f64),
            ]);
            base.derived(
                &format!("coordinator_{mode_name}_round_mean_s"),
                rr.mean_s,
            );
            base.derived(&format!("coordinator_{mode_name}_idle_s"), idle);
            base.derived(
                &format!("coordinator_{mode_name}_barrier_wait_s"),
                barrier,
            );
            base.derived(
                &format!("coordinator_{mode_name}_bonus_sweeps"),
                bonus as f64,
            );
            if *overlap {
                modeled_bulk = rs.modeled_bulk_s;
                modeled_overlapped = rs.modeled_overlapped_s;
                measured_serialized = rs.measured_serialized_s;
                measured_overlapped = rs.measured_overlapped_s;
            }
        }
        // modeled ratio from the overlapped run's own round (both
        // formulas are computed from the same measurements)
        if modeled_overlapped > 0.0 {
            base.derived(
                "coordinator_overlap_speedup_modeled",
                modeled_bulk / modeled_overlapped,
            );
        }
        // the REAL host overlap speedup, from one concurrent round's own
        // measurements: the wall it would have paid serializing the map
        // window + staging + shuffle/reduce tail, over the wall the
        // concurrent pipeline actually paid
        if measured_overlapped > 0.0 {
            base.derived(
                "coordinator_overlap_speedup_measured",
                measured_serialized / measured_overlapped,
            );
        }
        // informational cross-run ratio (bulk run's mean round wall over
        // the overlapped run's): chain states diverge across runs, so
        // this is noisier than the in-round measured ratio above
        if measured[1] > 0.0 {
            base.derived(
                "coordinator_overlap_host_round_ratio",
                measured[0] / measured[1],
            );
        }
    }

    base.write(Path::new("bench_results/BENCH_hotpath.json")).unwrap();
    if update_baseline() {
        base.write(Path::new("BENCH_hotpath.json")).unwrap();
    }
    fig.finish();
}
