//! Figure 2b: the posterior distribution of the Dirichlet concentration
//! parameter for balanced mixture configurations — #clusters from 128 to
//! 2048, data per cluster from 1024 to 4096.
//!
//! Computed exactly (grid quadrature of Eq. 6 — no Monte-Carlo noise),
//! at full paper scale (the computation is O(grid), independent of N).
//!
//! Expected shape: more clusters ⇒ posterior mass at larger α ⇒ more
//! headroom for parallelization; data-per-cluster moves it only weakly.

use clustercluster::bench::FigureEmitter;
use clustercluster::model::alpha::{alpha_posterior_grid, GammaPrior};

fn main() {
    let mut fig = FigureEmitter::new("fig2b_alpha_posterior");
    let prior = GammaPrior {
        shape: 1.0,
        rate: 0.01, // weakly informative over the whole relevant range
    };
    fig.note("exact grid quadrature of Eq. 6: p(α|z) ∝ p(α) Γ(α)/Γ(N+α) α^J");

    for &clusters in &[128u64, 256, 512, 1024, 2048] {
        for &per_cluster in &[1024u64, 2048, 4096] {
            let n = clusters * per_cluster;
            let (grid, p) = alpha_posterior_grid(n, clusters, &prior, 0.5, 5_000.0, 600);
            let mean: f64 = grid.iter().zip(&p).map(|(&g, &q)| g * q).sum();
            // 5% / 95% quantiles on the grid
            let mut acc = 0.0;
            let mut q05 = grid[0];
            let mut q95 = grid[grid.len() - 1];
            let mut seen05 = false;
            for (i, &q) in p.iter().enumerate() {
                acc += q;
                if !seen05 && acc >= 0.05 {
                    q05 = grid[i];
                    seen05 = true;
                }
                if acc >= 0.95 {
                    q95 = grid[i];
                    break;
                }
            }
            fig.row(&[
                ("clusters", clusters as f64),
                ("rows_per_cluster", per_cluster as f64),
                ("n", n as f64),
                ("alpha_mean", mean),
                ("alpha_q05", q05),
                ("alpha_q95", q95),
            ]);
        }
    }
    fig.note("paper shape: α grows with cluster count (128→2048 ⇒ roughly 16x)");
    fig.finish();
}
