//! Figure 2a: sampling efficiency (ESS per MCMC iteration) from the
//! PRIOR as a function of local sweeps per cross-machine update, for
//! several concentration parameters.
//!
//! Paper setup: Chinese-restaurant representation, 10 superclusters,
//! 1,000 data, 100,000 iterations, α ∈ {1, 10, 100}. Default here runs
//! 20,000 iterations (pass `--full` for the paper's 100k).
//!
//! Expected shape: efficiency roughly independent of the sweep ratio and
//! increasing with α.

use clustercluster::bench::{is_full_scale, FigureEmitter};
use clustercluster::metrics::ess::ess_per_iteration;
use clustercluster::rng::{categorical, Pcg64};
use clustercluster::supercluster::{sample_shuffle, ShuffleKernel};

/// Prior-only nested CRP chain: data are featureless tokens; transition
/// operators are exactly the coordinator's (local CRP Gibbs with
/// concentration αμ_k + cluster shuffle), with the likelihood terms
/// identically 1.
struct PriorChain {
    /// cluster id per datum
    z: Vec<usize>,
    /// cluster -> supercluster
    s: Vec<usize>,
    /// cluster sizes (0 = dead slot)
    sizes: Vec<u64>,
    free: Vec<usize>,
    k: usize,
    alpha: f64,
    mu: Vec<f64>,
}

impl PriorChain {
    fn init(n: usize, k: usize, alpha: f64, rng: &mut Pcg64) -> Self {
        let mu = vec![1.0 / k as f64; k];
        let mut c = PriorChain {
            z: vec![0; n],
            s: Vec::new(),
            sizes: Vec::new(),
            free: Vec::new(),
            k,
            alpha,
            mu,
        };
        // two-stage CRP prior draw: datum picks supercluster by DM
        // popularity, then a local table
        let mut data_per_super = vec![0.0f64; k];
        for i in 0..n {
            let w: Vec<f64> = (0..k)
                .map(|kk| alpha * c.mu[kk] + data_per_super[kk])
                .collect();
            let kk = categorical(rng, &w);
            c.z[i] = c.assign_local(i, kk, rng);
            data_per_super[kk] += 1.0;
        }
        c
    }

    /// choose a table for datum i within supercluster kk (prior weights)
    fn assign_local(&mut self, _i: usize, kk: usize, rng: &mut Pcg64) -> usize {
        let mut ids: Vec<usize> = Vec::new();
        let mut w: Vec<f64> = Vec::new();
        for (j, &sj) in self.s.iter().enumerate() {
            if sj == kk && self.sizes[j] > 0 {
                ids.push(j);
                w.push(self.sizes[j] as f64);
            }
        }
        ids.push(usize::MAX);
        w.push(self.alpha * self.mu[kk]);
        let pick = categorical(rng, &w);
        if ids[pick] == usize::MAX {
            let j = match self.free.pop() {
                Some(j) => {
                    self.s[j] = kk;
                    self.sizes[j] = 1;
                    j
                }
                None => {
                    self.s.push(kk);
                    self.sizes.push(1);
                    self.s.len() - 1
                }
            };
            j
        } else {
            self.sizes[ids[pick]] += 1;
            ids[pick]
        }
    }

    /// one local Gibbs sweep (datum stays on its supercluster)
    fn local_sweep(&mut self, rng: &mut Pcg64) {
        for i in 0..self.z.len() {
            let old = self.z[i];
            let kk = self.s[old];
            self.sizes[old] -= 1;
            if self.sizes[old] == 0 {
                self.free.push(old);
            }
            self.z[i] = self.assign_local(i, kk, rng);
        }
    }

    /// cross-machine update: Gibbs on every cluster's supercluster
    fn shuffle(&mut self, rng: &mut Pcg64) {
        let mut j_counts = vec![0u64; self.k];
        for (j, &sj) in self.s.iter().enumerate() {
            if self.sizes[j] > 0 {
                j_counts[sj] += 1;
            }
        }
        for j in 0..self.s.len() {
            if self.sizes[j] == 0 {
                continue;
            }
            let mut jm = j_counts.clone();
            jm[self.s[j]] -= 1;
            let knew = sample_shuffle(rng, ShuffleKernel::Exact, self.alpha, &self.mu, &jm);
            j_counts[self.s[j]] -= 1;
            j_counts[knew] += 1;
            self.s[j] = knew;
        }
    }

    fn num_clusters(&self) -> usize {
        self.sizes.iter().filter(|&&s| s > 0).count()
    }
}

fn main() {
    let iters: usize = if is_full_scale() { 100_000 } else { 20_000 };
    let n = 1_000;
    let k = 10;
    let mut fig = FigureEmitter::new("fig2a_ess");
    fig.note(&format!(
        "prior-only nested CRP: N={n}, K={k} superclusters, {iters} iterations; \
         statistic = ESS/iter of the total-cluster-count chain"
    ));

    for &alpha in &[1.0f64, 10.0, 100.0] {
        for &sweeps_per_shuffle in &[1usize, 2, 5, 10, 20] {
            let mut rng = Pcg64::seed_from(1000 + alpha as u64 + sweeps_per_shuffle as u64);
            let mut chain = PriorChain::init(n, k, alpha, &mut rng);
            let mut js: Vec<f64> = Vec::with_capacity(iters);
            for it in 0..iters {
                chain.local_sweep(&mut rng);
                if (it + 1) % sweeps_per_shuffle == 0 {
                    chain.shuffle(&mut rng);
                }
                js.push(chain.num_clusters() as f64);
            }
            let eff = ess_per_iteration(&js);
            fig.row(&[
                ("alpha", alpha),
                ("local_sweeps_per_shuffle", sweeps_per_shuffle as f64),
                ("ess_per_iter", eff),
                ("mean_clusters", clustercluster::util::mean(&js)),
            ]);
        }
    }
    fig.note("paper shape: ESS/iter ~flat in the sweep ratio, increasing with alpha");
    fig.finish();
}
