//! Figure 10: cluster coherence — binary feature vectors from a single
//! inferred cluster show "significant compression" relative to random
//! rows of the corpus. Quantified here as mean pairwise Hamming distance
//! within the largest inferred clusters vs a corpus-random baseline.

use clustercluster::bench::{is_full_scale, FigureEmitter};
use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::tinyimages::{generate, mean_hamming, TinyImagesConfig};
use clustercluster::rng::Pcg64;
use std::collections::HashMap;

fn main() {
    let full = is_full_scale();
    let cfg = TinyImagesConfig {
        n: if full { 50_000 } else { 5_000 },
        side: 16,
        categories: 30,
        features: 64,
        calibration_rows: if full { 5_000 } else { 1_200 },
        noise: 0.35,
        seed: 10,
    };
    let corpus = generate(&cfg);
    let mut fig = FigureEmitter::new("fig10_compression");

    let ccfg = CoordinatorConfig {
        workers: 32,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(101);
    let mut coord = Coordinator::new(&corpus.features, ccfg, &mut rng);
    let rounds = if full { 60 } else { 40 };
    for _ in 0..rounds {
        coord.step(&mut rng);
    }

    let z = coord.assignments();
    let mut members: HashMap<u32, Vec<usize>> = HashMap::new();
    for (r, &zi) in z.iter().enumerate() {
        members.entry(zi).or_default().push(r);
    }
    let mut clusters: Vec<&Vec<usize>> = members.values().collect();
    clusters.sort_by_key(|v| std::cmp::Reverse(v.len()));

    let random: Vec<usize> = (0..corpus.features.rows()).step_by(13).take(64).collect();
    let baseline = mean_hamming(&corpus.features, &random);
    fig.row(&[
        ("random_baseline_hamming_bits", baseline),
        ("features", cfg.features as f64),
    ]);

    let mut ratios = Vec::new();
    for (rank, cl) in clusters.iter().take(5).enumerate() {
        if cl.len() < 4 {
            continue;
        }
        let within = mean_hamming(&corpus.features, cl);
        let ratio = baseline / within.max(1e-9);
        ratios.push(ratio);
        fig.row(&[
            ("cluster_rank", rank as f64),
            ("cluster_size", cl.len() as f64),
            ("within_hamming_bits", within),
            ("compression_ratio", ratio),
        ]);
    }
    let mean_ratio = clustercluster::util::mean(&ratios);
    fig.row(&[("mean_compression_ratio_top5", mean_ratio)]);
    fig.note("paper shape: within-cluster feature vectors are visibly more coherent than random rows (ratio > 1)");
    fig.finish();

    assert!(
        mean_ratio > 1.0,
        "inferred clusters should compress the corpus (got ratio {mean_ratio})"
    );
}
