//! Figure 8: saturation — "communication costs and convergence slowdown
//! overwhelm per-iteration parallelism gains". Paper workload: 500k rows
//! / 1024 clusters, K ∈ {2, 8, 32, 128} (max 64 machines).
//!
//! Default: 10k rows / 64 clusters with the comm model scaled to keep the
//! paper's overhead:compute ratio; `--full` scales the workload up. The
//! expected shape: time-to-target improves up to a saturation point, then
//! regresses as the per-round communication term dominates.

use clustercluster::bench::{is_full_scale, FigureEmitter};
use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::mapreduce::CommModel;
use clustercluster::rng::Pcg64;
use clustercluster::runtime::auto_scorer;
use clustercluster::serial::calibrate_alpha;

fn main() {
    let full = is_full_scale();
    let (n, clusters, d, max_rounds) = if full {
        (500_000, 1024, 256, 100)
    } else {
        (50_000, 128, 64, 60)
    };
    let ds = SyntheticConfig {
        n,
        d,
        clusters,
        beta: 0.15,
        seed: 8,
    }
    .generate();
    let eval_rows: Vec<usize> = (0..ds.test.rows().min(1_000)).collect();
    let test = ds.test.select_rows(&eval_rows);
    let h = ds.true_entropy_estimate();
    let target = -h * 1.05;
    let mut scorer = auto_scorer();
    let mut fig = FigureEmitter::new("fig8_saturation");
    fig.note(&format!("N={n}, true J={clusters}; target loglik {target:.4}"));

    let comm = CommModel {
        round_latency_s: 0.01,
        per_worker_latency_s: 0.0005,
        bandwidth_bytes_per_s: 100e6,
    };
    let mut cal_rng = Pcg64::seed_from(88);
    let alpha0 = calibrate_alpha(&ds.train, 0.05, 10, &mut cal_rng);

    for &k in &[2usize, 8, 32, 128] {
        let cfg = CoordinatorConfig {
            workers: k,
            init_alpha: alpha0,
            comm,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(80 + k as u64);
        let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
        let mut t_target = f64::NAN;
        let mut comm_fraction = 0.0;
        for round in 0..max_rounds {
            let rs = coord.step(&mut rng);
            comm_fraction = comm.round_time(k, rs.bytes_transferred) / rs.modeled_wall_s;
            if round % 2 == 1 {
                let ll = coord.predictive_loglik(&test, scorer.as_mut());
                if ll >= target {
                    t_target = coord.modeled_time_s;
                    break;
                }
            }
        }
        fig.row(&[
            ("k", k as f64),
            ("t_target_s", t_target),
            ("t_per_round_s", coord.modeled_time_s / coord.rounds as f64),
            ("comm_fraction_of_round", comm_fraction),
        ]);
    }
    fig.note("paper shape: faster to saturation, then slower (comm-dominated) beyond");
    fig.finish();
}
