//! Figure 9: vector quantization of the Tiny-Images(-substitute) corpus
//! with 32 workers — convergence of predictive accuracy and cluster count
//! against modeled wall-clock.
//!
//! The corpus is the synthetic substitute documented in DESIGN.md §2 (the
//! real dataset is unavailable offline), processed by the paper's own
//! feature pipeline: randomized PCA on a calibration subset, then per-
//! component median binarization. Default 5k × 64 features; `--full`
//! approaches paper scale.

use clustercluster::bench::{is_full_scale, FigureEmitter};
use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::tinyimages::{generate, TinyImagesConfig};
use clustercluster::rng::Pcg64;
use clustercluster::runtime::auto_scorer;

fn main() {
    let full = is_full_scale();
    let cfg = if full {
        TinyImagesConfig {
            n: 200_000,
            side: 24,
            categories: 1000,
            features: 256,
            calibration_rows: 20_000,
            noise: 0.35,
            seed: 9,
        }
    } else {
        TinyImagesConfig {
            n: 5_000,
            side: 16,
            categories: 30,
            features: 64,
            calibration_rows: 1_200,
            noise: 0.35,
            seed: 9,
        }
    };
    let rounds = if full { 80 } else { 40 };
    let mut fig = FigureEmitter::new("fig9_tinyimages");
    fig.note(&format!(
        "synthetic tiny-images: {} rows, {} latent categories, {} binary features",
        cfg.n, cfg.categories, cfg.features
    ));
    let corpus = generate(&cfg);

    // 90/10 train/test split on the featurized corpus
    let n = corpus.features.rows();
    let n_test = n / 10;
    let train_rows: Vec<usize> = (0..n - n_test).collect();
    let test_rows: Vec<usize> = (n - n_test..n).collect();
    let train = corpus.features.select_rows(&train_rows);
    let test = corpus.features.select_rows(&test_rows);

    let ccfg = CoordinatorConfig {
        workers: 32,
        ..Default::default()
    };
    let mut rng = Pcg64::seed_from(91);
    let mut coord = Coordinator::new(&train, ccfg, &mut rng);
    let mut scorer = auto_scorer();
    let mut ts = Vec::new();
    let mut lls = Vec::new();
    let mut js = Vec::new();
    for _ in 0..rounds {
        coord.step(&mut rng);
        ts.push(coord.modeled_time_s);
        lls.push(coord.predictive_loglik(&test, scorer.as_mut()));
        js.push(coord.num_clusters() as f64);
    }
    fig.series("predictive_loglik", &ts, &lls);
    fig.series("num_clusters", &ts, &js);
    fig.row(&[
        ("final_loglik", *lls.last().unwrap()),
        ("final_clusters", *js.last().unwrap()),
        ("latent_categories", cfg.categories as f64),
    ]);
    fig.note("paper shape: steady compression progress; cluster count converges to the data's granularity");
    fig.finish();
}
