//! Figure 5: "Our parallel sampler constructs accurate density estimates
//! for many synthetic data sources" — a grid over dataset size and true
//! cluster count; each run must converge to a predictive probability
//! close to the true entropy of the generating mixture.
//!
//! Paper grid: 200k–1MM rows, 128–2048 clusters, 256 dims. Default here
//! is the laptop-scale image (5k–20k rows, 16–128 clusters, 64 dims);
//! pass `--full` for a paper-scale grid (slow on one core).

use clustercluster::bench::{is_full_scale, FigureEmitter};
use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::mapreduce::CommModel;
use clustercluster::metrics::adjusted_rand_index;
use clustercluster::rng::Pcg64;
use clustercluster::runtime::auto_scorer;
use clustercluster::serial::calibrate_alpha;

fn main() {
    let full = is_full_scale();
    let grid: Vec<(usize, usize, usize)> = if full {
        // (rows, clusters, dims)
        vec![
            (200_000, 128, 256),
            (200_000, 512, 256),
            (500_000, 1024, 256),
            (1_000_000, 2048, 256),
        ]
    } else {
        vec![
            (5_000, 16, 64),
            (10_000, 32, 64),
            (10_000, 64, 64),
            (20_000, 128, 64),
        ]
    };
    let rounds = if full { 120 } else { 50 };
    let mut scorer = auto_scorer();
    let mut fig = FigureEmitter::new("fig5_density");
    fig.note(&format!("scorer = {}", scorer.name()));

    for (idx, &(n, clusters, d)) in grid.iter().enumerate() {
        let ds = SyntheticConfig {
            n,
            d,
            clusters,
            beta: 0.05,
            seed: 500 + idx as u64,
        }
        .generate();
        let h = ds.true_entropy_estimate();
        let mut rng = Pcg64::seed_from(idx as u64);
        let alpha0 = calibrate_alpha(&ds.train, 0.05, 10, &mut rng);
        let cfg = CoordinatorConfig {
            workers: 8,
            init_alpha: alpha0,
            comm: CommModel::free(),
            ..Default::default()
        };
        let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
        for _ in 0..rounds {
            coord.step(&mut rng);
        }
        let ll = coord.predictive_loglik(&ds.test, scorer.as_mut());
        let ari = adjusted_rand_index(&coord.assignments(), &ds.train_z);
        fig.row(&[
            ("rows", n as f64),
            ("true_clusters", clusters as f64),
            ("true_neg_entropy", -h),
            ("predictive_loglik", ll),
            ("gap_nats", ll + h),
            ("inferred_clusters", coord.num_clusters() as f64),
            ("ari", ari),
        ]);
    }
    fig.note("paper shape: predictive probability lands near the true entropy line");
    fig.finish();
}
