//! Figure 6: convergence of predictive density (fast) and latent
//! structure (slow) against modeled wall-clock, for 2 / 8 / 32 compute
//! nodes, two seeds each.
//!
//! Paper workload: 2048 clusters / 200k rows. Default here: 64 clusters /
//! 10k rows (`--full` for a scaled-up run). Expected shapes: all node
//! counts converge to the true test likelihood; parallel gains up to ~8
//! nodes then saturation; cluster-count convergence much slower than
//! predictive convergence.
//!
//! Ablation (DESIGN.md §10): pass `--no-shuffle` to watch the isolated-
//! islands chain plateau above the true likelihood.

use clustercluster::bench::{is_full_scale, FigureEmitter};
use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::mapreduce::CommModel;
use clustercluster::rng::Pcg64;
use clustercluster::runtime::auto_scorer;
use clustercluster::serial::calibrate_alpha;

fn main() {
    let full = is_full_scale();
    let no_shuffle = std::env::args().any(|a| a == "--no-shuffle");
    let (n, clusters, d, rounds) = if full {
        (200_000, 512, 256, 80)
    } else {
        (10_000, 64, 64, 40)
    };
    let ds = SyntheticConfig {
        n,
        d,
        clusters,
        beta: 0.05,
        seed: 6,
    }
    .generate();
    let h = ds.true_entropy_estimate();
    let mut scorer = auto_scorer();
    let mut fig = FigureEmitter::new(if no_shuffle {
        "fig6_convergence_noshuffle"
    } else {
        "fig6_convergence"
    });
    fig.note(&format!(
        "N={n}, true J={clusters}, D={d}; ground-truth test loglik ≈ {:.4}",
        -h
    ));

    let comm = CommModel {
        round_latency_s: 0.05,
        per_worker_latency_s: 0.002,
        bandwidth_bytes_per_s: 50e6,
    };
    let mut cal_rng = Pcg64::seed_from(99);
    let alpha0 = calibrate_alpha(&ds.train, 0.05, 10, &mut cal_rng);

    for &k in &[2usize, 8, 32] {
        for seed in 0..2u64 {
            let cfg = CoordinatorConfig {
                workers: k,
                init_alpha: alpha0,
                shuffle: !no_shuffle,
                comm,
                ..Default::default()
            };
            let mut rng = Pcg64::seed_from(60 + seed * 100 + k as u64);
            let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
            let mut ts = Vec::new();
            let mut lls = Vec::new();
            let mut js = Vec::new();
            for _ in 0..rounds {
                coord.step(&mut rng);
                ts.push(coord.modeled_time_s);
                lls.push(coord.predictive_loglik(&ds.test, scorer.as_mut()));
                js.push(coord.num_clusters() as f64);
            }
            fig.series(&format!("loglik_k{k}_seed{seed}"), &ts, &lls);
            fig.series(&format!("clusters_k{k}_seed{seed}"), &ts, &js);
            fig.row(&[
                ("k", k as f64),
                ("seed", seed as f64),
                ("final_loglik", *lls.last().unwrap()),
                ("final_clusters", *js.last().unwrap()),
                ("true_neg_entropy", -h),
                ("true_clusters", clusters as f64),
            ]);
        }
    }
    fig.note("paper shape: loglik converges quickly for all K; #clusters drifts slowly");
    fig.finish();
}
