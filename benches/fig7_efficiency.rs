//! Figure 7: parallel efficiency up to 32 workers on the larger workload
//! ("1MM rows and 512 clusters") — more data and more clusters afford
//! more parallel opportunity; no latent-structure convergence slowdown.
//!
//! Default: 20k rows / 128 clusters; `--full` scales toward the paper's
//! configuration. Metric: modeled time to reach within 8% of the true
//! test likelihood, and the speedup relative to the slowest converged
//! worker count.

use clustercluster::bench::{is_full_scale, FigureEmitter};
use clustercluster::coordinator::{Coordinator, CoordinatorConfig};
use clustercluster::data::synthetic::SyntheticConfig;
use clustercluster::mapreduce::CommModel;
use clustercluster::metrics::adjusted_rand_index;
use clustercluster::rng::Pcg64;
use clustercluster::runtime::auto_scorer;
use clustercluster::serial::calibrate_alpha;

fn main() {
    let full = is_full_scale();
    let (n, clusters, d, max_rounds) = if full {
        (1_000_000, 512, 256, 120)
    } else {
        (50_000, 128, 64, 60)
    };
    // β=0.15: moderately-overlapping components (the well-separated β≪1
    // regime traps single-site Gibbs in merged-cluster modes at low K —
    // see EXPERIMENTS.md)
    let ds = SyntheticConfig {
        n,
        d,
        clusters,
        beta: 0.15,
        seed: 7,
    }
    .generate();
    // 1k-row eval subset keeps the PJRT eval off the bench's critical path
    let eval_rows: Vec<usize> = (0..ds.test.rows().min(1_000)).collect();
    let test = ds.test.select_rows(&eval_rows);
    let h = ds.true_entropy_estimate();
    let target = -h * 1.05;
    let mut scorer = auto_scorer();
    let mut fig = FigureEmitter::new("fig7_efficiency");
    fig.note(&format!(
        "N={n}, true J={clusters}; target loglik {target:.4} (true ≈ {:.4})",
        -h
    ));

    // overhead:compute ratio scaled with the miniature workload (paper:
    // Hadoop-era seconds of job latency against minutes of map compute)
    let comm = CommModel {
        round_latency_s: 0.01,
        per_worker_latency_s: 0.0005,
        bandwidth_bytes_per_s: 100e6,
    };
    let mut cal_rng = Pcg64::seed_from(77);
    let alpha0 = calibrate_alpha(&ds.train, 0.05, 10, &mut cal_rng);

    let mut base: Option<f64> = None;
    for &k in &[1usize, 2, 4, 8, 16, 32] {
        let cfg = CoordinatorConfig {
            workers: k,
            init_alpha: alpha0,
            comm,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(70 + k as u64);
        let mut coord = Coordinator::new(&ds.train, cfg, &mut rng);
        let mut t_target = None;
        for round in 0..max_rounds {
            coord.step(&mut rng);
            if round % 2 == 1 {
                let ll = coord.predictive_loglik(&test, scorer.as_mut());
                if ll >= target {
                    t_target = Some(coord.modeled_time_s);
                    break;
                }
            }
        }
        let ari = adjusted_rand_index(&coord.assignments(), &ds.train_z);
        match t_target {
            Some(t) => {
                if base.is_none() {
                    base = Some(t);
                }
                fig.row(&[
                    ("k", k as f64),
                    ("t_target_s", t),
                    ("speedup_vs_first", base.unwrap() / t),
                    ("final_clusters", coord.num_clusters() as f64),
                    ("ari", ari),
                ]);
            }
            None => fig.row(&[
                ("k", k as f64),
                ("t_target_s", f64::NAN),
                ("final_clusters", coord.num_clusters() as f64),
                ("ari", ari),
            ]),
        }
    }
    fig.note("paper shape: efficiencies persist to 32 workers at this scale");
    fig.finish();
}
