# Layer-1 correctness: Pallas kernel vs. the pure-jnp oracle (ref.py).
# This is the CORE correctness signal for the compiled artifacts.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bernoulli_loglik as bl
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand_problem(rng, b, d, j):
    x = (rng.random((b, d)) < 0.5).astype(np.float32)
    p = rng.uniform(0.05, 0.95, size=(d, j)).astype(np.float32)
    w1 = np.log(p)
    w0 = np.log1p(-p)
    return x, w1, w0


def test_kernel_matches_ref_default_shape():
    rng = np.random.default_rng(0)
    x, w1, w0 = rand_problem(rng, 256, 256, 512)
    got = bl.loglik_matrix_from_w(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w0))
    want = ref.loglik_matrix_ref(x, w1, w0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


def test_kernel_single_block():
    rng = np.random.default_rng(1)
    x, w1, w0 = rand_problem(rng, 8, 16, 8)
    got = bl.loglik_matrix_from_w(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w0))
    want = ref.loglik_matrix_ref(x, w1, w0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


def test_kernel_multiblock_k_accumulation():
    # D spans several k-blocks: exercises the o_ref revisiting accumulator.
    rng = np.random.default_rng(2)
    x, w1, w0 = rand_problem(rng, 16, 1024, 16)
    got = bl.loglik_matrix_from_w(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w0))
    want = ref.loglik_matrix_ref(x, w1, w0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=5e-4)


def test_all_zero_and_all_one_rows():
    # x=0 rows score colsum(W0); x=1 rows score colsum(W1).
    d, j = 32, 8
    rng = np.random.default_rng(3)
    _, w1, w0 = rand_problem(rng, 1, d, j)
    x = np.vstack([np.zeros((4, d)), np.ones((4, d))]).astype(np.float32)
    got = np.asarray(
        bl.loglik_matrix_from_w(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w0))
    )
    np.testing.assert_allclose(got[:4], np.broadcast_to(w0.sum(0), (4, j)), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(got[4:], np.broadcast_to(w1.sum(0), (4, j)), rtol=1e-5, atol=1e-4)


def test_padding_dims_are_exact_noops():
    # Pad D with W1=W0=0 — scores must not change (log 1 contributions).
    rng = np.random.default_rng(4)
    x, w1, w0 = rand_problem(rng, 8, 16, 8)
    base = np.asarray(bl.loglik_matrix_from_w(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w0)))
    xp = np.hstack([x, np.zeros((8, 16), np.float32)])
    w1p = np.vstack([w1, np.zeros((16, 8), np.float32)])
    w0p = np.vstack([w0, np.zeros((16, 8), np.float32)])
    padded = np.asarray(bl.loglik_matrix_from_w(jnp.asarray(xp), jnp.asarray(w1p), jnp.asarray(w0p)))
    np.testing.assert_allclose(padded, base, rtol=1e-6, atol=1e-5)


BLOCK = st.sampled_from([8, 16, 32])
NBLK = st.integers(min_value=1, max_value=3)


@settings(max_examples=25, deadline=None)
@given(bm=BLOCK, bn=BLOCK, bk=BLOCK, nb=NBLK, nd=NBLK, nj=NBLK, seed=st.integers(0, 2**31 - 1))
def test_kernel_hypothesis_shape_sweep(bm, bn, bk, nb, nd, nj, seed):
    """Property: kernel == oracle for every tiling of every shape."""
    b, d, j = bm * nb, bk * nd, bn * nj
    rng = np.random.default_rng(seed)
    x, w1, w0 = rand_problem(rng, b, d, j)
    wd = w1 - w0
    bias = w0.sum(axis=0, keepdims=True)
    got = bl.loglik_matrix(
        jnp.asarray(x), jnp.asarray(wd), jnp.asarray(bias), bm=bm, bn=bn, bk=bk
    )
    want = ref.loglik_matrix_ref(x, w1, w0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_hypothesis_sparse_binary(seed):
    """Skewed binary densities (mostly-0 / mostly-1 rows) stay exact."""
    rng = np.random.default_rng(seed)
    b, d, j = 16, 64, 16
    dens = rng.uniform(0.0, 1.0, size=(b, 1))
    x = (rng.random((b, d)) < dens).astype(np.float32)
    p = rng.uniform(0.01, 0.99, size=(d, j)).astype(np.float32)
    w1, w0 = np.log(p), np.log1p(-p)
    got = bl.loglik_matrix_from_w(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w0))
    want = ref.loglik_matrix_ref(x, w1, w0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=5e-4)


def test_misaligned_shape_raises():
    with pytest.raises(AssertionError):
        bl.loglik_matrix(
            jnp.zeros((100, 64)), jnp.zeros((64, 64)), jnp.zeros((1, 64)),
            bm=64, bn=64, bk=64,
        )
