# AOT pipeline smoke: every variant lowers to HLO text that the XLA text
# parser (and hence the Rust runtime) can consume, and the manifest
# format matches what rust/src/runtime/pjrt.rs parses.
import os
import tempfile

import jax

from compile import aot


def test_variants_cover_expected_entries():
    names = [v[0] for v in aot.variants()]
    entries = {v[1] for v in aot.variants()}
    assert {"loglik", "density", "density_stats"} <= entries
    assert len(names) == len(set(names)), "duplicate variant names"


def test_small_variant_lowers_to_hlo_text():
    # smallest variant only (full lowering is exercised by `make artifacts`)
    small = min(aot.variants(), key=lambda v: v[2] * v[3] * v[4])
    name, entry, b, d, j, argspec, fn = small
    lowered = jax.jit(fn).lower(*argspec())
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    # the scoring graph must contain a dot (the Pallas matmul lowered
    # through interpret mode) and the output shape
    assert "dot(" in text or "dot " in text, "no dot op in lowered HLO"


def test_manifest_roundtrip_format(tmp_path=None):
    with tempfile.TemporaryDirectory() as td:
        # emulate main() manifest writing for two fake rows
        lines = ["a loglik 64 256 128 a.hlo.txt", "b density 256 256 512 b.hlo.txt"]
        mpath = os.path.join(td, "manifest.txt")
        with open(mpath, "w") as f:
            f.write("\n".join(lines) + "\n")
        for line in open(mpath):
            fields = line.split()
            assert len(fields) == 6
            int(fields[2]), int(fields[3]), int(fields[4])
