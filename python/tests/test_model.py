# Layer-2 correctness: model graph (suffstats → weights → kernel → density)
# vs. the literal oracle, plus the padding semantics the Rust runtime
# depends on.
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

NEG = -1.0e30  # padded-cluster log weight (matches the Rust runtime)


def rand_stats(rng, j, d):
    n = rng.integers(1, 50, size=j).astype(np.float32)
    c = np.stack([rng.integers(0, int(nj) + 1, size=d) for nj in n]).astype(np.float32)
    beta = rng.uniform(0.1, 3.0, size=d).astype(np.float32)
    return n, c, beta


def test_weights_from_suffstats_matches_ref():
    rng = np.random.default_rng(0)
    n, c, beta = rand_stats(rng, 16, 32)
    w1, w0 = model.weights_from_suffstats(jnp.asarray(n), jnp.asarray(c), jnp.asarray(beta))
    r1, r0 = ref.weights_from_suffstats_ref(n, c, beta)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(r1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(w0), np.asarray(r0), rtol=1e-6)


def test_weights_are_valid_log_probs():
    rng = np.random.default_rng(1)
    n, c, beta = rand_stats(rng, 8, 16)
    w1, w0 = model.weights_from_suffstats(jnp.asarray(n), jnp.asarray(c), jnp.asarray(beta))
    # exp(w1) + exp(w0) == 1 for every (d, j)
    np.testing.assert_allclose(np.exp(np.asarray(w1)) + np.exp(np.asarray(w0)), 1.0, rtol=1e-6)


def test_predictive_density_matches_ref():
    rng = np.random.default_rng(2)
    b, d, j = 16, 32, 8
    x = (rng.random((b, d)) < 0.5).astype(np.float32)
    p = rng.uniform(0.1, 0.9, size=(d, j)).astype(np.float32)
    w1, w0 = np.log(p), np.log1p(-p)
    pi = rng.dirichlet(np.ones(j)).astype(np.float32)
    logpi = np.log(pi)
    got = model.predictive_density(*map(jnp.asarray, (x, w1, w0, logpi)))
    want = ref.predictive_density_ref(x, w1, w0, logpi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


def test_density_stats_fused_path():
    rng = np.random.default_rng(3)
    b, d, j = 8, 16, 8
    x = (rng.random((b, d)) < 0.5).astype(np.float32)
    n, c, beta = rand_stats(rng, j, d)
    logpi = np.log(rng.dirichlet(np.ones(j))).astype(np.float32)
    got = model.predictive_density_from_stats(
        *map(jnp.asarray, (x, n, c, beta, logpi))
    )
    w1, w0 = ref.weights_from_suffstats_ref(n, c, beta)
    want = ref.predictive_density_ref(x, np.asarray(w1), np.asarray(w0), logpi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


def test_cluster_padding_with_neg_logpi_is_exact():
    """Padding J with logpi=-1e30 must reproduce the unpadded density.

    This is the contract the Rust runtime uses to run arbitrary J on a
    fixed-J artifact.
    """
    rng = np.random.default_rng(4)
    b, d, j, jpad = 8, 16, 8, 16
    x = (rng.random((b, d)) < 0.5).astype(np.float32)
    p = rng.uniform(0.1, 0.9, size=(d, j)).astype(np.float32)
    w1, w0 = np.log(p), np.log1p(-p)
    logpi = np.log(rng.dirichlet(np.ones(j))).astype(np.float32)
    base = model.predictive_density(*map(jnp.asarray, (x, w1, w0, logpi)))

    w1p = np.hstack([w1, np.zeros((d, jpad - j), np.float32)])
    w0p = np.hstack([w0, np.zeros((d, jpad - j), np.float32)])
    logpip = np.concatenate([logpi, np.full(jpad - j, NEG, np.float32)])
    padded = model.predictive_density(*map(jnp.asarray, (x, w1p, w0p, logpip)))
    np.testing.assert_allclose(np.asarray(padded), np.asarray(base), rtol=1e-6, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), j=st.sampled_from([1, 2, 8, 16]))
def test_density_monotone_in_weights_hypothesis(seed, j):
    """Upweighting the best-scoring cluster can only raise the density."""
    rng = np.random.default_rng(seed)
    b, d = 8, 16
    x = (rng.random((b, d)) < 0.5).astype(np.float32)
    p = rng.uniform(0.1, 0.9, size=(d, j)).astype(np.float32)
    w1, w0 = np.log(p), np.log1p(-p)
    logpi = np.log(rng.dirichlet(np.ones(j))).astype(np.float32)
    s = np.asarray(ref.loglik_matrix_ref(x, w1, w0))
    dens = np.asarray(model.predictive_density(*map(jnp.asarray, (x, w1, w0, logpi))))
    # density is logsumexp: must dominate every single component term
    per_component = s + logpi[None, :]
    assert np.all(dens >= per_component.max(axis=1) - 1e-4)
