# AOT pipeline: lower the Layer-2 entry points to HLO **text** artifacts
# for the Rust PJRT runtime.
#
# HLO text, NOT lowered.compile()/.serialize(): jax >= 0.5 emits
# HloModuleProto with 64-bit instruction ids, which the published `xla`
# crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The HLO
# *text* parser reassigns ids, so text round-trips cleanly.
# (See /opt/xla-example/gen_hlo.py and its README.)
#
# Usage:  cd python && python -m compile.aot --out-dir ../artifacts
# Emits one <name>.hlo.txt per (entry-point, shape) variant plus
# manifest.txt, which the Rust runtime parses:
#     <name> <entry> <B> <D> <J> <file>
import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# (name, entry, B, D, J, example-arg builder). The Rust runtime pads any
# workload onto these compiled shapes (pad dims: W1=W0=0; pad clusters:
# logpi=-1e30; pad rows: ignored) and chunks larger B/J over repeated calls.
def variants():
    out = []
    for (b, d, j) in [(256, 256, 512), (64, 256, 128)]:
        out.append((
            f"loglik_{b}x{d}x{j}", "loglik", b, d, j,
            lambda b=b, d=d, j=j: (spec(b, d), spec(d, j), spec(d, j)),
            model.loglik_matrix,
        ))
        out.append((
            f"density_{b}x{d}x{j}", "density", b, d, j,
            lambda b=b, d=d, j=j: (spec(b, d), spec(d, j), spec(d, j), spec(j)),
            model.predictive_density,
        ))
    b, d, j = 256, 256, 512
    out.append((
        f"density_stats_{b}x{d}x{j}", "density_stats", b, d, j,
        lambda: (spec(b, d), spec(j), spec(j, d), spec(d), spec(j)),
        model.predictive_density_from_stats,
    ))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, entry, b, d, j, argspec, fn in variants():
        lowered = jax.jit(fn).lower(*argspec())
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {entry} {b} {d} {j} {fname}")
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.txt")
    with open(mpath, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {mpath} ({len(manifest_lines)} variants)")


if __name__ == "__main__":
    main()
