# Layer 2 — the JAX compute graph for ClusterCluster's scoring hot path.
#
# The paper's model (§6): Dirichlet-process mixture of product-Bernoulli
# components with per-dimension Beta(β_d, β_d) priors, coin weights
# collapsed out. The dense, parallel compute is *scoring*: a block of
# binary data against a block of clusters. These functions call the
# Layer-1 Pallas kernel (kernels/bernoulli_loglik.py) so the whole graph
# lowers into one HLO module per entry point (python/compile/aot.py).
#
# Build-time only: Rust executes the lowered artifacts via PJRT; Python
# never runs on the sampling path.
import jax.numpy as jnp
from jax.scipy.special import logsumexp

from .kernels import bernoulli_loglik


def loglik_matrix(x, w1, w0):
    """[B,J] log p(x_b | cluster j) from log predictive weight matrices.

    x:  [B, D] f32 binary data block (0.0/1.0)
    w1: [D, J] f32 log p̂_jd
    w0: [D, J] f32 log (1 - p̂_jd)
    """
    return bernoulli_loglik.loglik_matrix_from_w(x, w1, w0)


def predictive_density(x, w1, w0, logpi):
    """[B] log predictive mixture density: logsumexp_j (S[b,j] + logpi[j]).

    This is the metric series of Figs. 5/6/7/8/9 (test-set predictive
    log-likelihood). Padded clusters carry logpi = -1e30.
    """
    s = loglik_matrix(x, w1, w0)
    return logsumexp(s + logpi[None, :], axis=1)


def weights_from_suffstats(n, c, beta):
    """(W1, W0) from cluster sufficient statistics (collapsed predictive).

    n:    [J]    f32 datum counts per cluster
    c:    [J, D] f32 per-dimension one-counts
    beta: [D]    f32 Beta(β_d, β_d) hyperparameters
    p̂_jd = (c_jd + β_d) / (n_j + 2 β_d); padded clusters (n=0, c=0, β>0)
    yield p̂ = 1/2 — harmless, they are masked by logpi downstream.
    """
    denom = n[:, None] + 2.0 * beta[None, :]
    p1 = (c + beta[None, :]) / denom
    return jnp.log(p1).T, jnp.log1p(-p1).T


def predictive_density_from_stats(x, n, c, beta, logpi):
    """Fused end-to-end entry: suffstats → weights → kernel → density.

    The shape the Rust runtime feeds after every reduce step: cluster
    stats are what the coordinator actually holds; the weight transform
    fuses into the same HLO module.
    """
    w1, w0 = weights_from_suffstats(n, c, beta)
    return predictive_density(x, w1, w0, logpi)
