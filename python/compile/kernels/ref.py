# Pure-jnp correctness oracle for the Pallas kernels.
#
# pytest compares every kernel against these references (the CORE
# correctness signal for Layer 1). They are written in the most literal,
# element-wise form of the math — no algebraic shortcuts shared with the
# kernel — so agreement is meaningful.
#
# Math (paper §6, collapsed Beta-Bernoulli clusters):
#   For binary datum x (D-dim) and cluster j with "coin" posterior
#   predictive p̂_jd, the log predictive likelihood is
#       log p(x | j) = Σ_d [ x_d·log(p̂_jd) + (1-x_d)·log(1-p̂_jd) ]
#   With W1[d,j] = log(p̂_jd), W0[d,j] = log(1-p̂_jd) this is the [B,J]
#   matrix   S = X·W1 + (1-X)·W0.
import jax.numpy as jnp
from jax.scipy.special import logsumexp


def loglik_matrix_ref(x, w1, w0):
    """Literal oracle: S[b,j] = sum_d x[b,d]*w1[d,j] + (1-x[b,d])*w0[d,j].

    x:  [B, D] float (entries 0.0/1.0 — binary data as floats)
    w1: [D, J] log predictive prob of a 1 in dim d under cluster j
    w0: [D, J] log predictive prob of a 0
    returns [B, J] float32
    """
    return jnp.einsum("bd,dj->bj", x, w1) + jnp.einsum("bd,dj->bj", 1.0 - x, w0)


def predictive_density_ref(x, w1, w0, logpi):
    """Oracle for the fused mixture predictive density.

    logpi: [J] log mixture weights (−inf/−1e30 for padded clusters)
    returns [B] float32: log Σ_j π_j p(x_b | j)
    """
    s = loglik_matrix_ref(x, w1, w0)
    return logsumexp(s + logpi[None, :], axis=1)


def weights_from_suffstats_ref(n, c, beta):
    """Collapsed Beta-Bernoulli predictive weights from sufficient stats.

    n:    [J] datum counts per cluster
    c:    [J, D] per-dimension one-counts per cluster
    beta: [D] symmetric Beta(β_d, β_d) hyperparameters
    returns (w1 [D,J], w0 [D,J]) log predictive probabilities
        p̂_jd = (c_jd + β_d) / (n_j + 2 β_d)
    """
    denom = n[:, None] + 2.0 * beta[None, :]  # [J, D]
    p1 = (c + beta[None, :]) / denom
    return jnp.log(p1).T, jnp.log1p(-p1).T
