# Layer 1 — Pallas kernel for the DPM scoring hot-spot.
#
# Computes the [B, J] collapsed Beta-Bernoulli log-likelihood matrix
#     S = X·W1 + (1-X)·W0
# via the algebraic identity (X is 0/1-valued)
#     S = X·(W1 - W0) + colsum(W0)
# i.e. ONE matmul plus a column bias — the MXU-systolic shape. The
# HBM↔VMEM schedule is expressed with BlockSpec: the grid tiles
# (B, J, D) into (bm, bn, bk) VMEM blocks, accumulating over the D axis
# (innermost grid dim, so the output block stays resident across the
# k-loop). See DESIGN.md §3 (Hardware adaptation) and §8 (Perf).
#
# interpret=True ALWAYS: real-TPU lowering emits a Mosaic custom-call the
# CPU PJRT plugin cannot execute. Correctness is pinned to ref.py by
# python/tests/test_kernel.py (including hypothesis shape sweeps).
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default TPU-shaped tile sizes (f32): VMEM per grid step is
#   bm·bk + bk·bn + bn + bm·bn  floats = (128·256 + 256·128 + 128 + 128·128)·4B
#   ≈ 320 KiB  — about 2% of a 16 MiB VMEM, leaving ample double-buffer room.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256


def _loglik_kernel(x_ref, wd_ref, bias_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ wd[k,j]  (+ bias at k=0).

    The output BlockSpec maps every k to the same (i, j) block, so the
    accumulator lives in VMEM across the whole k-loop (k is the innermost
    grid dimension — sequential on TPU).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        # bias_ref is a [1, bn] block of colsum(W0); broadcast over rows.
        o_ref[...] = jnp.broadcast_to(bias_ref[...], o_ref.shape)

    # MXU matmul: force f32 accumulation regardless of input dtype.
    o_ref[...] += jnp.dot(
        x_ref[...], wd_ref[...], preferred_element_type=jnp.float32
    )
    del nk  # shape bookkeeping only; kept for signature clarity


def loglik_matrix(x, wd, bias, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Pallas-tiled S = X @ Wd + bias  with Wd = W1-W0, bias = colsum(W0).

    x:    [B, D] f32 (0.0/1.0 entries)
    wd:   [D, J] f32
    bias: [1, J] f32
    returns [B, J] f32

    Shapes must divide the block sizes; callers (model.py / the Rust
    runtime) pad to the compiled artifact shape. Padding is exact:
    pad dims carry W1=W0=0 (log 1), pad rows are ignored downstream,
    pad clusters get logpi = -1e30.
    """
    b, d = x.shape
    d2, j = wd.shape
    assert d == d2 and bias.shape == (1, j), (x.shape, wd.shape, bias.shape)
    bm, bn, bk = min(bm, b), min(bn, j), min(bk, d)
    assert b % bm == 0 and j % bn == 0 and d % bk == 0, (
        f"shapes ({b},{d},{j}) must tile by ({bm},{bk},{bn})"
    )
    nk = d // bk
    grid = (b // bm, j // bn, nk)
    return pl.pallas_call(
        functools.partial(_loglik_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, jj, k: (i, k)),  # X tile
            pl.BlockSpec((bk, bn), lambda i, jj, k: (k, jj)),  # Wd tile
            pl.BlockSpec((1, bn), lambda i, jj, k: (0, jj)),  # bias tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, jj, k: (i, jj)),
        out_shape=jax.ShapeDtypeStruct((b, j), jnp.float32),
        interpret=True,
    )(x, wd, bias)


def loglik_matrix_from_w(x, w1, w0, **kw):
    """Convenience wrapper taking (W1, W0) directly (the L2 entry point)."""
    wd = w1 - w0
    bias = jnp.sum(w0, axis=0, keepdims=True)
    return loglik_matrix(x, wd, bias, **kw)
